"""Host-side execution resources: thread pools and static per-device queues.

HPXCL attaches every device operation to a lightweight user-level thread
under the *static* scheduling policy (one queue pinned per device — paper
§3/§4).  The JAX analogue: a ``WorkQueue`` is a single-thread FIFO executor;
one is created per logical device for ordered submission (XLA then overlaps
the *execution*), plus a shared host pool for continuations, I/O and
``async_`` tasks.
"""
from __future__ import annotations

import atexit
import concurrent.futures as _cf
import os
import queue as _queue
import threading
from typing import Callable, Optional

from repro.core.futures import Future

__all__ = ["WorkQueue", "Runtime", "get_runtime", "reset_runtime"]


class WorkQueue:
    """Single-worker FIFO queue — the 'static scheduling policy' of HPXCL.

    Submissions execute strictly in order; each returns a ``Future``.  This
    is the submission-ordering analogue of a CUDA stream (DESIGN.md §2).
    """

    def __init__(self, name: str):
        self.name = name
        self._q: _queue.SimpleQueue = _queue.SimpleQueue()
        self._shutdown = threading.Event()
        self._thread = threading.Thread(target=self._loop, name=f"wq:{name}", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            if type(item) is list:  # batched enqueue (submit_many)
                for sub in item:
                    self._run_one(sub)
            else:
                self._run_one(item)

    @staticmethod
    def _run_one(item) -> None:
        fut, fn, args, kwargs = item
        if fut._cf.set_running_or_notify_cancel():
            try:
                fut._cf.set_result(fn(*args, **kwargs))
            except BaseException as e:  # noqa: BLE001
                fut._cf.set_exception(e)

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
        self._q.put((fut, fn, args, kwargs))
        return fut

    def submit_many(self, calls) -> "list[Future]":
        """Batched enqueue: one queue hop for N calls (DESIGN.md §8).

        ``calls`` is an iterable of callables or ``(fn, args)`` /
        ``(fn, args, kwargs)`` tuples.  The batch occupies a single queue
        slot, so the per-submission put/wakeup cost is paid once; the
        calls still run strictly in the given order, uninterleaved with
        other submissions.  Returns one ``Future`` per call.
        """
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        batch = []
        futs: "list[Future]" = []
        for c in calls:
            if callable(c):
                fn, args, kwargs = c, (), {}
            else:
                fn = c[0]
                args = c[1] if len(c) > 1 else ()
                kwargs = c[2] if len(c) > 2 else {}
            fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
            futs.append(fut)
            batch.append((fut, fn, args, kwargs))
        if batch:
            self._q.put(batch)
        return futs

    def drain(self) -> None:
        """Block until everything submitted so far has run."""
        self.submit(lambda: None).get()

    def shutdown(self) -> None:
        if not self._shutdown.is_set():
            self._shutdown.set()
            self._q.put(None)
            self._thread.join(timeout=5)


class Runtime:
    """Process-wide execution resources (HPX thread-manager analogue)."""

    def __init__(self, host_workers: Optional[int] = None):
        # generous: workers mostly *wait* (device readiness, queue results,
        # file I/O), so oversubscription is the deadlock-safe choice
        n = host_workers or max(32, 4 * (os.cpu_count() or 1))
        self.pool = _cf.ThreadPoolExecutor(max_workers=n, thread_name_prefix="repro-host")
        self._queues: dict[str, WorkQueue] = {}
        self._lock = threading.Lock()

    def queue(self, name: str) -> WorkQueue:
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = WorkQueue(name)
            return q

    def async_(self, fn: Callable, *args, **kwargs) -> Future:
        return Future.from_concurrent(self.pool.submit(fn, *args, **kwargs))

    def shutdown(self) -> None:
        with self._lock:
            queues, self._queues = list(self._queues.values()), {}
        for q in queues:
            q.shutdown()
        self.pool.shutdown(wait=False)


_runtime: Optional[Runtime] = None
_runtime_lock = threading.Lock()


def get_runtime() -> Runtime:
    global _runtime
    if _runtime is None:
        with _runtime_lock:
            if _runtime is None:
                _runtime = Runtime()
                atexit.register(_runtime.shutdown)
    return _runtime


def reset_runtime() -> None:
    """Tear down and replace the global runtime (tests)."""
    global _runtime
    with _runtime_lock:
        if _runtime is not None:
            _runtime.shutdown()
        _runtime = None
