"""Host-side execution resources: thread pools and static per-device queues.

HPXCL attaches every device operation to a lightweight user-level thread
under the *static* scheduling policy (one queue pinned per device — paper
§3/§4).  The JAX analogue: a ``WorkQueue`` is a single-thread FIFO executor;
one is created per logical device for ordered submission (XLA then overlaps
the *execution*), plus a shared host pool for continuations, I/O and
``async_`` tasks.

Load accounting (DESIGN.md §9): every queue counts submissions and
completions and tracks how long its worker has been busy, so a placement
policy (``least_loaded``) can read a real backlog signal off
``WorkQueue.load()`` instead of guessing.  Counters are monotonically
increasing; the snapshot is advisory (reads are unsynchronized with the
worker by design — scheduling decisions tolerate a stale-by-one view).
"""
from __future__ import annotations

import atexit
import concurrent.futures as _cf
import os
import queue as _queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.futures import Future

__all__ = ["QueueLoad", "WorkQueue", "Runtime", "get_runtime", "reset_runtime"]


@dataclass(frozen=True)
class QueueLoad:
    """Snapshot of one queue's backlog (the ``least_loaded`` signal).

    ``depth`` counts submissions not yet completed (queued + running);
    ``inflight`` is 1 while the worker is inside a task; ``busy_for`` is
    how long the current task has been running (0.0 when idle) and
    ``busy_time`` the lifetime total of task execution seconds.
    """

    depth: int
    inflight: int
    busy_for: float
    busy_time: float
    submitted: int
    completed: int


class WorkQueue:
    """Single-worker FIFO queue — the 'static scheduling policy' of HPXCL.

    Submissions execute strictly in order; each returns a ``Future``.  This
    is the submission-ordering analogue of a CUDA stream (DESIGN.md §2).
    """

    def __init__(self, name: str):
        self.name = name
        self._q: _queue.SimpleQueue = _queue.SimpleQueue()
        self._shutdown = threading.Event()
        # Load accounting: _submitted is bumped under _count_lock (many
        # submitter threads); _completed/_busy_* have a single writer (the
        # worker) and need no lock.
        self._count_lock = threading.Lock()
        self._submitted = 0
        self._completed = 0
        self._busy_time = 0.0
        self._busy_since: "float | None" = None
        self._thread = threading.Thread(target=self._loop, name=f"wq:{name}", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            if type(item) is list:  # batched enqueue (submit_many)
                for sub in item:
                    self._run_one(sub)
            else:
                self._run_one(item)
            # Drop the reference while blocked in get(): a worker idling on
            # an empty queue must not pin its last result (the futures keep
            # results alive for their owners; the queue should not).
            del item

    def _run_one(self, item) -> None:
        fut, fn, args, kwargs = item
        self._busy_since = time.monotonic()
        try:
            if fut._cf.set_running_or_notify_cancel():
                try:
                    fut._cf.set_result(fn(*args, **kwargs))
                except BaseException as e:  # noqa: BLE001
                    fut._cf.set_exception(e)
        finally:
            t0, self._busy_since = self._busy_since, None
            self._busy_time += time.monotonic() - t0
            self._completed += 1

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
        with self._count_lock:
            self._submitted += 1
        self._q.put((fut, fn, args, kwargs))
        return fut

    def submit_many(self, calls) -> "list[Future]":
        """Batched enqueue: one queue hop for N calls (DESIGN.md §8).

        ``calls`` is an iterable of callables or ``(fn, args)`` /
        ``(fn, args, kwargs)`` tuples.  The batch occupies a single queue
        slot, so the per-submission put/wakeup cost is paid once; the
        calls still run strictly in the given order, uninterleaved with
        other submissions.  Returns one ``Future`` per call.
        """
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        batch = []
        futs: "list[Future]" = []
        for c in calls:
            if callable(c):
                fn, args, kwargs = c, (), {}
            else:
                fn = c[0]
                args = c[1] if len(c) > 1 else ()
                kwargs = c[2] if len(c) > 2 else {}
            fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
            futs.append(fut)
            batch.append((fut, fn, args, kwargs))
        if batch:
            with self._count_lock:
                self._submitted += len(batch)
            self._q.put(batch)
        return futs

    def load(self) -> QueueLoad:
        """Advisory backlog snapshot (see module docstring)."""
        submitted, completed = self._submitted, self._completed
        since = self._busy_since
        now = time.monotonic()
        busy_for = (now - since) if since is not None else 0.0
        return QueueLoad(
            depth=max(0, submitted - completed),
            inflight=1 if since is not None else 0,
            busy_for=busy_for,
            busy_time=self._busy_time,
            submitted=submitted,
            completed=completed,
        )

    def drain(self) -> None:
        """Block until everything submitted so far has run."""
        self.submit(lambda: None).get()

    def shutdown(self) -> None:
        if not self._shutdown.is_set():
            self._shutdown.set()
            self._q.put(None)
            self._thread.join(timeout=5)


class Runtime:
    """Process-wide execution resources (HPX thread-manager analogue)."""

    def __init__(self, host_workers: Optional[int] = None):
        # generous: workers mostly *wait* (device readiness, queue results,
        # file I/O), so oversubscription is the deadlock-safe choice
        n = host_workers or max(32, 4 * (os.cpu_count() or 1))
        self.pool = _cf.ThreadPoolExecutor(max_workers=n, thread_name_prefix="repro-host")
        self._queues: dict[str, WorkQueue] = {}
        self._lock = threading.Lock()

    def queue(self, name: str) -> WorkQueue:
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = WorkQueue(name)
            return q

    def async_(self, fn: Callable, *args, **kwargs) -> Future:
        return Future.from_concurrent(self.pool.submit(fn, *args, **kwargs))

    def shutdown(self) -> None:
        with self._lock:
            queues, self._queues = list(self._queues.values()), {}
        for q in queues:
            q.shutdown()
        self.pool.shutdown(wait=False)


_runtime: Optional[Runtime] = None
_runtime_lock = threading.Lock()


def get_runtime() -> Runtime:
    global _runtime
    if _runtime is None:
        with _runtime_lock:
            if _runtime is None:
                _runtime = Runtime()
                atexit.register(_runtime.shutdown)
    return _runtime


def reset_runtime() -> None:
    """Tear down and replace the global runtime (tests).

    Cached ``Device`` objects hold ``WorkQueue``s owned by the runtime
    being torn down; leaving them cached means the next ``submit`` hits a
    dead queue ("WorkQueue ... is shut down").  The device cache and the
    default scheduler (which holds ``Device`` handles) are therefore
    dropped with the runtime — the next discovery re-registers devices
    against the fresh runtime's queues.

    Live parcelports are drained and shut down FIRST: their remote-device
    proxy queues belong to the runtime being torn down, and their cluster
    worker *processes* must never outlive the session that spawned them
    (a leaked worker would survive the test run).
    """
    import sys

    _parcel = sys.modules.get("repro.core.parcel")
    if _parcel is not None:  # never import the transport just to reset it
        _parcel._shutdown_all_ports()
    global _runtime
    with _runtime_lock:
        if _runtime is not None:
            _runtime.shutdown()
        _runtime = None
    # Local imports: device/scheduler import this module at top level.
    from repro.core import device as _device
    from repro.core import scheduler as _scheduler

    _device._on_runtime_reset()
    _scheduler._on_runtime_reset()
