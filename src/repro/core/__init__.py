"""Core futurized accelerator runtime (the paper's contribution).

Public surface mirrors HPXCL:

    from repro.core import get_all_devices, Dim3, when_all, wait_all, dataflow

    devices = get_all_devices(1, 0).get()          # Listing 1
    dev = devices[0]
    buf = dev.create_buffer(1000, jnp.float32).get()
    futs = [buf.enqueue_write(0, host_data)]
    prog = dev.create_program_with_file("kernel.py").get()
    futs.append(prog.build("sum"))
    wait_all(futs)                                  # Listing 2, line 38
    prog.run([buf, res, n], "sum", grid=Dim3(1), block=Dim3(32), out=[res]).get()
    result = res.enqueue_read_sync()
"""
from repro.core.agas import GID, Placement, Registry, registry
from repro.core.buffer import Buffer
from repro.core.device import Device, get_all_devices
from repro.core.executor import Runtime, WorkQueue, get_runtime, reset_runtime
from repro.core.futures import (
    Future,
    FutureState,
    Promise,
    async_,
    dataflow,
    make_exceptional_future,
    make_ready_future,
    wait_all,
    when_all,
    when_any,
)
from repro.core.graph import GraphExec, GraphResult, TaskGraph, capture, current_graph
from repro.core.program import Dim3, Program

__all__ = [
    "GID",
    "Placement",
    "Registry",
    "registry",
    "Buffer",
    "Device",
    "get_all_devices",
    "Runtime",
    "WorkQueue",
    "get_runtime",
    "reset_runtime",
    "Future",
    "FutureState",
    "Promise",
    "async_",
    "dataflow",
    "make_exceptional_future",
    "make_ready_future",
    "wait_all",
    "when_all",
    "when_any",
    "Dim3",
    "Program",
    "TaskGraph",
    "GraphExec",
    "GraphResult",
    "capture",
    "current_graph",
]
