"""Core futurized accelerator runtime (the paper's contribution).

Public surface mirrors HPXCL:

    from repro.core import get_all_devices, Dim3, when_all, wait_all, dataflow

    devices = get_all_devices(1, 0).get()          # Listing 1
    dev = devices[0]
    buf = dev.create_buffer(1000, jnp.float32).get()
    futs = [buf.enqueue_write(0, host_data)]
    prog = dev.create_program_with_file("kernel.py").get()
    futs.append(prog.build("sum"))
    wait_all(futs)                                  # Listing 2, line 38
    prog.run([buf, res, n], "sum", grid=Dim3(1), block=Dim3(32), out=[res]).get()
    result = res.enqueue_read_sync()

Streams (DESIGN.md §11) give transfer–compute overlap on one device —
independent chains run on their own lanes, same-stream order is FIFO:

    s1, s2 = dev.create_stream(), dev.create_stream()
    s1.enqueue_write(a, 0, host_a); prog.launch([a], "k", out=[ra], stream=s1)
    s2.enqueue_write(b, 0, host_b); prog.launch([b], "k", out=[rb], stream=s2)
    done = s1.record()                                # cudaEventRecord
    s2.wait_event(done)                               # cudaStreamWaitEvent

Scheduler-routed launches (DESIGN.md §9) drop the explicit device:

    sched = Scheduler(policy="least_loaded")          # or affinity/round_robin
    prog.run_on_any([buf], "sum", out=[res], scheduler=sched).get()

Cluster-wide launches (DESIGN.md §10) drop the explicit *locality*:

    port = LocalClusterParcelport(n_workers=2)        # or LoopbackParcelport
    prog.run_on_any([buf], "sum", cluster=port).get() # hpx::async(locality, action)
"""
from repro.core.agas import GID, HOST_KEY, Placement, Registry, locality_of, registry, set_locality_id
from repro.core.buffer import Buffer
from repro.core.device import (
    Device,
    Locality,
    RemoteBuffer,
    RemoteDevice,
    get_all_devices,
    get_all_localities,
)
from repro.core.executor import (
    Lane,
    LaneDispatcher,
    QueueLoad,
    Runtime,
    WorkQueue,
    coalesce,
    flush_coalesced,
    get_runtime,
    reset_runtime,
)
from repro.core.futures import (
    Future,
    FutureState,
    Promise,
    async_,
    dataflow,
    make_exceptional_future,
    make_ready_future,
    wait_all,
    when_all,
    when_any,
)
from repro.core.graph import GraphExec, GraphResult, TaskGraph, capture, current_graph
from repro.core.parcel import (
    LocalClusterParcelport,
    LoopbackParcelport,
    Parcel,
    Parcelport,
    RemoteError,
    register_kernel,
)
from repro.core.program import Dim3, Program, RemoteProgram
from repro.core.stream import Event, Stream
from repro.core.scheduler import (
    AffinityPolicy,
    LeastLoadedPolicy,
    PercolationPolicy,
    PlacementPolicy,
    RoundRobinPolicy,
    Scheduler,
    StaticPolicy,
    get_scheduler,
    make_policy,
    set_scheduler,
)

__all__ = [
    "GID",
    "HOST_KEY",
    "Placement",
    "Registry",
    "registry",
    "locality_of",
    "set_locality_id",
    "Buffer",
    "Device",
    "Locality",
    "RemoteDevice",
    "RemoteBuffer",
    "get_all_devices",
    "get_all_localities",
    "Parcel",
    "Parcelport",
    "LoopbackParcelport",
    "LocalClusterParcelport",
    "RemoteError",
    "register_kernel",
    "Runtime",
    "WorkQueue",
    "Lane",
    "LaneDispatcher",
    "QueueLoad",
    "get_runtime",
    "reset_runtime",
    "coalesce",
    "flush_coalesced",
    "Stream",
    "Event",
    "PlacementPolicy",
    "StaticPolicy",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "AffinityPolicy",
    "PercolationPolicy",
    "Scheduler",
    "get_scheduler",
    "set_scheduler",
    "make_policy",
    "Future",
    "FutureState",
    "Promise",
    "async_",
    "dataflow",
    "make_exceptional_future",
    "make_ready_future",
    "wait_all",
    "when_all",
    "when_any",
    "Dim3",
    "Program",
    "RemoteProgram",
    "TaskGraph",
    "GraphExec",
    "GraphResult",
    "capture",
    "current_graph",
]
