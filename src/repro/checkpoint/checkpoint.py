"""Asynchronous checkpointing — the paper's Fig. 5 pattern at framework
scale: training never blocks on file I/O; the device->host snapshot and
the serialization both run as futures on the runtime's queues, overlapped
with the next training step (``hpx::async`` writing the Mandelbrot PNG
while the GPU computes the next image).

Format: one ``.npz`` per top-level group + a JSON manifest holding the
tree structure, shapes/dtypes, step, RNG key, data-pipeline cursor and the
mesh the state was saved under.  Restore re-shards onto *any* mesh
(elastic restart): arrays are loaded on host and ``device_put`` with the
target sharding.
"""
from __future__ import annotations

import json
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core.executor import get_runtime
from repro.core.futures import Future

_SEP = "/"

# Only fully-published checkpoints look like this; a writer killed
# mid-save leaves ``step_XXXXXXXX.tmp`` behind, which must never be
# listed (it may hold a torn npz) and is swept on the next manager.
_STEP_DIR = re.compile(r"^step_(\d{8})$")


def _flatten(tree) -> "dict[str, np.ndarray]":
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    """Double-buffered async checkpointing with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._writer = get_runtime().queue(f"ckpt-writer:{directory}")
        self._pending: "Optional[Future]" = None
        self._lock = threading.Lock()
        self._sweep_torn()

    def _sweep_torn(self) -> None:
        """Remove staging dirs a killed writer left behind.  Single-writer
        discipline (one manager per directory) makes this safe: any
        ``.tmp`` visible to a fresh manager is an orphan, never in-flight."""
        for d in self.dir.glob("step_*.tmp"):
            if d.is_dir():
                shutil.rmtree(d, ignore_errors=True)

    # -- save ---------------------------------------------------------------

    def save_async(self, step: int, state: Any, extra: "dict | None" = None) -> Future:
        """Snapshot ``state`` and write it in the background.

        Returns a future completing when the checkpoint is durable.  If the
        previous save hasn't drained yet we wait for it first (double
        buffering — bounded memory, paper Fig. 5 discussion).
        """
        with self._lock:
            if self._pending is not None and not self._pending.done():
                self._pending.wait()

        # 1) device -> host snapshot (blocks only for transfer, not I/O)
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(state)

        # 2) serialize on the writer queue (off the training thread)
        def _write():
            t0 = time.time()
            step_dir = self.dir / f"step_{step:08d}"
            tmp = step_dir.with_suffix(".tmp")
            if tmp.exists():  # a crashed writer's leftovers must not leak
                shutil.rmtree(tmp)  # into the directory we publish
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "keys": sorted(host.keys()),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
                "extra": extra or {},
                "written_at": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if step_dir.exists():  # re-save of a restored step
                shutil.rmtree(step_dir)
            tmp.rename(step_dir)  # atomic publish: torn state never visible
            self._gc()
            return {"step": step, "seconds": time.time() - t0, "path": str(step_dir)}

        fut = self._writer.submit(_write)
        with self._lock:
            self._pending = fut
        return fut

    def wait(self) -> None:
        with self._lock:
            p = self._pending
        if p is not None:
            p.wait()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            d = self.dir / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    # -- restore --------------------------------------------------------------

    def steps(self) -> "list[int]":
        """Fully-published checkpoint steps only: the name filter skips
        ``.tmp`` staging dirs (a writer killed mid-save must never surface
        as ``latest_step`` — atomicity is publish-by-rename)."""
        out = []
        for d in self.dir.glob("step_*"):
            m = _STEP_DIR.match(d.name)
            if m and d.is_dir():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> "Optional[int]":
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: "Optional[int]" = None, shardings: Any = None):
        """Load a checkpoint into the structure of ``like``.

        ``shardings``: optional pytree of NamedShardings (same structure) —
        enables *elastic* restore onto a different mesh than the one saved
        under; arrays are device_put with the new sharding.
        Returns (state, extra).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((step_dir / "manifest.json").read_text())
        with np.load(step_dir / "arrays.npz") as z:
            host = {k: z[k] for k in z.files}

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = []
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]:
            keys.append(_SEP.join(_path_str(p) for p in path))
        assert len(keys) == len(leaves_like)
        new_leaves = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(keys)
        )
        for k, ref, sh in zip(keys, leaves_like, shard_leaves):
            if k not in host:
                raise KeyError(f"checkpoint {step_dir} missing leaf {k}")
            arr = host[k].astype(ref.dtype)
            if sh is not None:
                new_leaves.append(jax.device_put(arr, sh))
            else:
                new_leaves.append(jax.numpy.asarray(arr))
        state = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return state, manifest.get("extra", {})
