"""Oracle for the Mandelbrot escape-iteration kernel (paper Fig. 5)."""
import jax
import jax.numpy as jnp


def mandelbrot_ref(height: int, width: int, max_iter: int = 64,
                   x_range=(-2.0, 1.0), y_range=(-1.5, 1.5)):
    xs = jnp.linspace(x_range[0], x_range[1], width)
    ys = jnp.linspace(y_range[0], y_range[1], height)
    cr, ci = jnp.meshgrid(xs, ys)

    def body(_, st):
        zr, zi, it = st
        live = zr * zr + zi * zi <= 4.0
        zr2 = zr * zr - zi * zi + cr
        zi2 = 2 * zr * zi + ci
        zr = jnp.where(live, zr2, zr)
        zi = jnp.where(live, zi2, zi)
        it = it + live.astype(jnp.int32)
        return zr, zi, it

    zr = jnp.zeros((height, width), jnp.float32)
    zi = jnp.zeros((height, width), jnp.float32)
    it = jnp.zeros((height, width), jnp.int32)
    _, _, it = jax.lax.fori_loop(0, max_iter, body, (zr, zi, it))
    return it
