"""Pallas TPU kernel: Mandelbrot escape iterations (paper Fig. 5 workload).

Grid tiles the image; each step derives its pixel coordinates from
``pl.program_id`` + iota (no input operands at all), runs the fixed-trip
escape loop on VPU registers, and writes the iteration-count tile.
Complex arithmetic is explicit (zr, zi) — TPU Pallas has no complex dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mandel_kernel(o_ref, *, bh, bw, width, height, max_iter, x0, x1, y0, y1):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = i * bh + jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 0)
    cols = j * bw + jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 1)
    cr = x0 + cols * ((x1 - x0) / max(width - 1, 1))
    ci = y0 + rows * ((y1 - y0) / max(height - 1, 1))

    def body(_, st):
        zr, zi, it = st
        live = zr * zr + zi * zi <= 4.0
        zr2 = zr * zr - zi * zi + cr
        zi2 = 2.0 * zr * zi + ci
        zr = jnp.where(live, zr2, zr)
        zi = jnp.where(live, zi2, zi)
        return zr, zi, it + live.astype(jnp.int32)

    zr = jnp.zeros((bh, bw), jnp.float32)
    zi = jnp.zeros((bh, bw), jnp.float32)
    it = jnp.zeros((bh, bw), jnp.int32)
    _, _, it = jax.lax.fori_loop(0, max_iter, body, (zr, zi, it))
    o_ref[...] = it


@functools.partial(
    jax.jit, static_argnames=("height", "width", "max_iter", "block", "interpret")
)
def mandelbrot(
    *,
    height: int,
    width: int,
    max_iter: int = 64,
    block: "tuple[int, int]" = (128, 128),
    interpret: bool = True,
):
    bh, bw = block
    assert height % bh == 0 and width % bw == 0, (height, width, block)
    kern = functools.partial(
        _mandel_kernel,
        bh=bh, bw=bw, width=width, height=height, max_iter=max_iter,
        x0=-2.0, x1=1.0, y0=-1.5, y1=1.5,
    )
    return pl.pallas_call(
        kern,
        grid=(height // bh, width // bw),
        in_specs=[],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((height, width), jnp.int32),
        interpret=interpret,
    )()
