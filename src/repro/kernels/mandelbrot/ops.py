"""Jit'd op + KERNELS registry (Program.from_file target)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mandelbrot.kernel import mandelbrot as _pallas_mandel
from repro.kernels.mandelbrot.ref import mandelbrot_ref


def mandelbrot(size_arr, *, block=None, grid=None, impl: str = "auto", max_iter: int = 64):
    """size_arr: int32[2] = (height, width) — array so it can live in a
    Buffer; shapes must still be static, so we read concrete values."""
    import numpy as np

    h, w = (int(x) for x in np.asarray(size_arr))
    blk = tuple(block[:2]) if isinstance(block, (tuple, list)) else (128, 128)
    if impl == "ref" or (impl == "auto" and (h % blk[0] or w % blk[1])):
        return mandelbrot_ref(h, w, max_iter)
    return _pallas_mandel(
        height=h, width=w, max_iter=max_iter, block=blk,
        interpret=jax.default_backend() != "tpu",
    )


KERNELS = {"mandelbrot": mandelbrot, "mandelbrot_ref": lambda s, **k: mandelbrot_ref(int(s[0]), int(s[1]))}
