"""Public paged-attention op for decode serving.

``impl="auto"`` picks the Pallas kernel on TPU (where the scalar-prefetch
page gather runs in the DMA engine) and the gather-based reference
everywhere else: interpret-mode Pallas executes the ``(B, H, M)`` grid as
a Python loop, far too slow for the serving hot path, while the reference
is one fused XLA gather+einsum.  ``impl="kernel"`` forces the Pallas path
(interpret mode off-TPU) so tests exercise the real kernel logic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_bhd
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    impl: str = "auto"):
    """q: (B, H, D); k/v_pages: (N, P, K, D); page_table: (B, M) int32;
    lengths: (B,) int32 -> (B, H, D)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "ref" or (impl == "auto" and not on_tpu):
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths)
    return paged_attention_bhd(q, k_pages, v_pages, page_table, lengths,
                               interpret=not on_tpu)


def paged_attention_layers(q, k_pages, v_pages, page_table, lengths, *,
                           impl: str = "auto"):
    """Multi-layer paged attention over ONE folded slab (DESIGN.md §17).

    The zoo's page geometry keeps every layer's KV in a single slab with
    layer as the leading dim — one page table covers the whole model.
    q: (L, B, H, D); k/v_pages: (L, N, P, K, D); page_table: (B, M);
    lengths: (B,) -> (L, B, H, D).  ``L`` is static, so the python loop
    unrolls into one fused XLA computation (ref) or L kernel launches
    sharing the prefetched table (Pallas) — no per-layer table rebuilds,
    which is the point of folding.  GQA geometries (H a multiple of K)
    pass straight through to the per-layer op."""
    L = q.shape[0]
    if k_pages.shape[0] != L or v_pages.shape[0] != L:
        raise ValueError(
            f"layer dims disagree: q has {L}, k_pages {k_pages.shape[0]}, "
            f"v_pages {v_pages.shape[0]}")
    outs = [paged_attention(q[l], k_pages[l], v_pages[l], page_table,
                            lengths, impl=impl)
            for l in range(L)]
    return jnp.stack(outs, axis=0)


KERNELS = {
    "paged_attention": paged_attention,
    "paged_attention_layers": paged_attention_layers,
}
