"""Public paged-attention op for decode serving.

``impl="auto"`` picks the Pallas kernel on TPU (where the scalar-prefetch
page gather runs in the DMA engine) and the gather-based reference
everywhere else: interpret-mode Pallas executes the ``(B, H, M)`` grid as
a Python loop, far too slow for the serving hot path, while the reference
is one fused XLA gather+einsum.  ``impl="kernel"`` forces the Pallas path
(interpret mode off-TPU) so tests exercise the real kernel logic.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention_bhd
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    impl: str = "auto"):
    """q: (B, H, D); k/v_pages: (N, P, K, D); page_table: (B, M) int32;
    lengths: (B,) int32 -> (B, H, D)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "ref" or (impl == "auto" and not on_tpu):
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths)
    return paged_attention_bhd(q, k_pages, v_pages, page_table, lengths,
                               interpret=not on_tpu)


KERNELS = {"paged_attention": paged_attention}
