"""Pallas TPU paged attention (decode): page-table-indirect flash.

vLLM-style serving keeps every sequence's KV cache as fixed-size *pages*
scattered through a global per-device pool, so batches of different-length
sequences share ONE executable with zero token-padding waste (DESIGN.md
§15).  The kernel is the flash pattern of
``kernels/flash_attention/kernel.py`` — online softmax with running
``(m, l, acc)`` statistics in VMEM scratch over the innermost sequential
grid dimension — with one twist: the kv BlockSpec does not walk contiguous
sequence blocks, it walks the sequence's **page table**.

The page table and lengths ride ``PrefetchScalarGridSpec`` scalar-prefetch
arguments: they are available *before* the kernel body runs, so the kv
index map can compute the physical page for grid step ``(b, h, j)`` as
``table[b, j]`` and the DMA engine fetches exactly that page from the pool
in HBM — the gather lives in the index map, not in memory (the same trick
the flash kernel uses for GQA head grouping, ``h // R``).

Masking: pages at or beyond ``ceil(length / P)`` are skipped outright via
``pl.when`` (their table slots must still hold a valid page index — the
pool's slot 0 by convention — so the prefetched DMA stays in bounds); the
sequence's last partial page is masked elementwise against ``length``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    # Whole pages past the sequence's tail do no work at all.
    run = j * page_size < length

    @pl.when(run)
    def _step():
        q = q_ref[...].reshape(1, -1)          # (1, D)
        k = k_ref[...].reshape(page_size, -1)  # (P, D)
        v = v_ref[...].reshape(page_size, -1)  # (P, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (1.0 / math.sqrt(q.shape[-1]))     # (1, P)
        pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_bhd(q, k_pages, v_pages, page_table, lengths, *,
                        interpret: bool = True):
    """q: (B, H, D); k/v_pages: (N, P, K, D), H % K == 0;
    page_table: (B, M) int32; lengths: (B,) int32 -> (B, H, D)."""
    B, H, D = q.shape
    N, P, K, Dk = k_pages.shape
    M = page_table.shape[1]
    R = H // K
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=(B, H, M),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, j, tbl, ln: (b, h, 0)),
            pl.BlockSpec((1, P, 1, D), lambda b, h, j, tbl, ln: (tbl[b, j], 0, h // R, 0)),
            pl.BlockSpec((1, P, 1, D), lambda b, h, j, tbl, ln: (tbl[b, j], 0, h // R, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, tbl, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, page_size=P)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages, v_pages)
