"""Oracle: gather-based paged attention, fp32 softmax.

The pure-JAX reference for the Pallas paged-attention kernel: KV lives in
a global *page pool* — ``k_pages``/``v_pages`` of shape ``(num_pages,
page_size, K, D)`` — and each query row owns a ``page_table`` row of
physical page indices covering its first ``length`` tokens.  The oracle
simply gathers every table entry back into a contiguous ``(B, M*P, K, D)``
view and runs exact GQA attention with a length mask, which makes it both
the correctness anchor for the kernel and the executable definition of the
page-table layout:

* logical token ``t`` of sequence ``b`` lives at
  ``pages[table[b, t // P], t % P]``;
* table slots at or beyond ``ceil(length / P)`` are *padding* — they must
  hold a **valid** page index (conventionally 0) so gathers stay in
  bounds, and their tokens are masked out of the softmax by ``lengths``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """q: (B, H, D); k/v_pages: (N, P, K, D), H % K == 0;
    page_table: (B, M) int32; lengths: (B,) int32 -> (B, H, D).

    One decode query per sequence, attending to its first ``lengths[b]``
    cached tokens (no causal structure beyond the length mask: the query
    IS the last token).
    """
    B, H, D = q.shape
    N, P, K, Dk = k_pages.shape
    M = page_table.shape[1]
    R = H // K
    k = k_pages[page_table].reshape(B, M * P, K, Dk)  # gather: (B, M, P, K, D)
    v = v_pages[page_table].reshape(B, M * P, K, Dk)
    qr = q.reshape(B, K, R, D)
    s = jnp.einsum("bkrd,bskd->bkrs", qr, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(D)
    mask = jnp.arange(M * P)[None, :] < lengths[:, None]  # (B, M*P)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkrs,bskd->bkrd", w, v)
    return o.reshape(B, H, D).astype(q.dtype)
