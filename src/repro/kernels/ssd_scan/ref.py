"""Oracle for the SSD scan kernel: sequential (non-chunked) recurrence.

y_t = C_t . S_t + D x_t,  S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T —
the exact state-space recurrence the chunked/blocked forms must match.
"""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D=None):
    """x: (G, S, P); dt: (G, S); A: (G,); B/C: (G, S, N) -> (G, S, P).

    G = batch*heads flattened; one scalar A per head-group row.
    """
    G, S, P = x.shape
    N = B.shape[-1]

    def row(xg, dtg, ag, bg, cg):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            a = jnp.exp(dtt * ag)
            state = a * state + dtt * jnp.outer(bt, xt)  # (N, P)
            y = ct @ state  # (P,)
            return state, y

        s0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, s0, (xg, dtg, bg, cg))
        return ys

    y = jax.vmap(row)(
        x.astype(jnp.float32), dt.astype(jnp.float32), A.astype(jnp.float32),
        B.astype(jnp.float32), C.astype(jnp.float32),
    )
    if D is not None:
        y = y + x.astype(jnp.float32) * D[:, None, None]
    return y.astype(x.dtype)
