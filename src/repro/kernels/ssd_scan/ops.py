"""Public SSD op in model layout + KERNELS registry."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def ssd(x, dt, A, B, C, D=None, *, chunk: int = 64, impl: str = "auto"):
    """Model layout: x (Bz, S, H, P); dt (Bz, S, H); A (H,);
    B/C (Bz, S, H, N) (groups pre-expanded) -> (Bz, S, H, P)."""
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    G = Bz * H

    xg = x.transpose(0, 2, 1, 3).reshape(G, S, P)
    dtg = dt.transpose(0, 2, 1).reshape(G, S)
    Ag = jnp.broadcast_to(A[None], (Bz, H)).reshape(G)
    Bg = B.transpose(0, 2, 1, 3).reshape(G, S, N)
    Cg = C.transpose(0, 2, 1, 3).reshape(G, S, N)

    if impl == "ref" or (impl == "auto" and S % min(chunk, S)):
        yg = ssd_ref(xg, dtg, Ag, Bg, Cg)
    else:
        yg = ssd_scan(xg, dtg, Ag, Bg, Cg, chunk=min(chunk, S),
                      interpret=jax.default_backend() != "tpu")
    y = yg.reshape(Bz, H, S, P).transpose(0, 2, 1, 3)
    if D is not None:
        y = y + x * D[None, None, :, None].astype(x.dtype)
    return y


KERNELS = {"ssd": ssd}
