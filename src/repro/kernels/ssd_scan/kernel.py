"""Pallas TPU kernel: Mamba-2 SSD chunked scan (forward).

TPU adaptation (DESIGN.md §2): the CUDA SSD kernel stages chunks through
shared memory with warp-level matmuls; here each grid step owns one
(sequence-chunk x head-group) VMEM tile, the intra-chunk quadratic term
runs on the MXU as (L, L) dot products, and the inter-chunk state (N, P)
is carried in VMEM scratch across the sequential innermost grid dim —
exactly the role the CUDA version gives to its persistent accumulator.

Layout: G = batch*heads rows; per row: x (S, P), dt (S,), B/C (S, N),
A scalar brought in as a (1,1) block from a (G, 1) operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, L):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L,)
    a = a_ref[0, 0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)  # (L, N)
    C = c_ref[0].astype(jnp.float32)  # (L, N)

    da = dt * a  # (L,)
    cum = jnp.cumsum(da)  # (L,)

    # intra-chunk: att[i, j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j <= i
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(jj <= ii, scores * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # inter-chunk: incoming state contribution
    state = state_scr[...]  # (N, P)
    y += jax.lax.dot_general(
        C * jnp.exp(cum)[:, None], state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # state update: S <- exp(cum_L) S + sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    w = jnp.exp(cum[-1] - cum) * dt  # (L,)
    new_state = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        B * w[:, None], x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = new_state
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """x: (G, S, P); dt: (G, S); A: (G,); B/C: (G, S, N) -> (G, S, P)."""
    G, S, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    grid = (G, S // L)
    a2 = A.reshape(G, 1)
    kern = functools.partial(_ssd_kernel, L=L)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, L), lambda g, c: (g, c)),
            pl.BlockSpec((1, 1), lambda g, c: (g, 0)),
            pl.BlockSpec((1, L, N), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, L, N), lambda g, c: (g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, P), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((G, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, B, C)
