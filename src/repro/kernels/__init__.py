# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Kernel packages, each with a Pallas kernel, a jit'd op and a pure-jnp
oracle.  ``all_kernels()`` aggregates every package's ``KERNELS`` registry
(the ``Program.from_file`` / graph-capture launch surface) lazily, so
importing ``repro.kernels`` stays cheap."""
from __future__ import annotations

import importlib

_PACKAGES = ("flash_attention", "mandelbrot", "paged_attention", "partition_map",
             "ssd_scan", "stencil")


def all_kernels() -> "dict[str, callable]":
    """name -> callable over every kernel package's KERNELS registry
    (qualified as ``<package>.<kernel>`` on collision, bare otherwise)."""
    out: "dict[str, callable]" = {}
    for pkg in _PACKAGES:
        mod = importlib.import_module(f"repro.kernels.{pkg}.ops")
        for name, fn in getattr(mod, "KERNELS", {}).items():
            key = name if name not in out else f"{pkg}.{name}"
            out[key] = fn
    return out


__all__ = ["all_kernels"]
