"""Oracle for the partition benchmark kernel (paper Fig. 4/6):
k(x) = sqrt(sin^2 x + cos^2 x)  (the paper applies it to the index; we
apply it to the value — identical compute density, = 1 up to rounding)."""
import jax.numpy as jnp


def partition_map_ref(x):
    s, c = jnp.sin(x), jnp.cos(x)
    return jnp.sqrt(s * s + c * c)
