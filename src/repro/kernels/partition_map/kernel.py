"""Pallas TPU kernel for the partition benchmark map (paper Fig. 4/6).

Pure VPU workload — one block in VMEM per grid step.  The interesting
part of the paper's benchmark is not this kernel but the *pipelining*:
partitions stream through copy->compute->copy with futures overlapping
the stages (see benchmarks/fig4_partition.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _map_kernel(x_ref, o_ref):
    x = x_ref[...]
    s, c = jnp.sin(x), jnp.cos(x)
    o_ref[...] = jnp.sqrt(s * s + c * c)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def partition_map(x, *, block: int = 8192, interpret: bool = True):
    n = x.shape[0]
    assert n % block == 0, (n, block)
    return pl.pallas_call(
        _map_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
