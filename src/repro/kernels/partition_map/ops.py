"""Jit'd op + KERNELS registry (Program.from_file target)."""
from __future__ import annotations

import jax

from repro.kernels.partition_map.kernel import partition_map as _pallas_map
from repro.kernels.partition_map.ref import partition_map_ref


def partition_map(x, *, block=None, grid=None, impl: str = "auto"):
    blk = (block[0] if isinstance(block, (tuple, list)) else block) or 8192
    if impl == "ref" or (impl == "auto" and (x.shape[0] % blk or x.shape[0] < blk)):
        return partition_map_ref(x)
    return _pallas_map(x, block=blk, interpret=jax.default_backend() != "tpu")


KERNELS = {"partition_map": partition_map, "partition_map_ref": partition_map_ref}
