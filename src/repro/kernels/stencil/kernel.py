"""Pallas TPU kernel: PRK 3-point stencil (paper Fig. 3 workload).

Halo exchange via triple-BlockSpec: the same input array is bound three
times with index maps (i-1, i, i+1); each grid step reads its own block
plus one element of each neighbour block from VMEM.  Block size should be
a multiple of 1024 (8x128 f32 tiles) on real TPU; interpret mode validates
semantics on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(prev_ref, cur_ref, nxt_ref, o_ref):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    cur = cur_ref[...]
    left_halo = jnp.where(i == 0, jnp.zeros_like(prev_ref[-1]), prev_ref[-1])
    right_halo = jnp.where(i == n - 1, jnp.zeros_like(nxt_ref[0]), nxt_ref[0])
    shifted_l = jnp.concatenate([left_halo[None], cur[:-1]])
    shifted_r = jnp.concatenate([cur[1:], right_halo[None]])
    o_ref[...] = 0.5 * shifted_l + cur + 0.5 * shifted_r


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stencil(x, *, block: int = 1024, interpret: bool = True):
    """x: (N,) with N % block == 0."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    bs = lambda off: pl.BlockSpec(  # noqa: E731
        (block,), lambda i: (jnp.clip(i + off, 0, grid[0] - 1),)
    )
    return pl.pallas_call(
        _stencil_kernel,
        grid=grid,
        in_specs=[bs(-1), bs(0), bs(+1)],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, x)
