"""Pure-jnp oracle for the PRK 3-point stencil (paper Fig. 3):
s(x_i) = 0.5*x_{i-1} + x_i + 0.5*x_{i+1}, zero boundary."""
import jax.numpy as jnp


def stencil_ref(x):
    left = jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]])
    right = jnp.concatenate([x[1:], jnp.zeros_like(x[:1])])
    return 0.5 * left + x + 0.5 * right
