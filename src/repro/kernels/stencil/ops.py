"""Jit'd public op + KERNELS registry for the futurized runtime
(``device.create_program_with_file(".../stencil/ops.py")``)."""
from __future__ import annotations

import jax

from repro.kernels.stencil.kernel import stencil as _pallas_stencil
from repro.kernels.stencil.ref import stencil_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stencil(x, *, block=None, grid=None, impl: str = "auto"):
    """3-point stencil. ``impl``: auto|pallas|ref. ``block`` may come from
    the launch geometry (Dim3 -> tuple) of ``Program.run``."""
    blk = (block[0] if isinstance(block, (tuple, list)) else block) or 1024
    if impl == "ref" or (impl == "auto" and (x.shape[0] % blk or x.shape[0] < blk)):
        return stencil_ref(x)
    return _pallas_stencil(x, block=blk, interpret=not _on_tpu())


KERNELS = {"stencil": stencil, "stencil_ref": stencil_ref}
