"""Oracle: exact (unfused) GQA attention, fp32 softmax."""
import math

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D); H % K == 0 -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    R = H // K
    qr = q.reshape(B, Sq, K, R, D)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qr, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkrqs,bskd->bqkrd", w, v)
    return o.reshape(B, Sq, H, D)
