"""Pallas TPU flash attention (forward): blocked online softmax.

TPU adaptation of the FlashAttention idea (DESIGN.md §2): the CUDA version
tiles over SM shared memory; here blocks are BlockSpec-mapped VMEM tiles
sized for the MXU (128-aligned), and the kv-block loop is the *innermost
sequential grid dimension* with the running (m, l, acc) statistics carried
in VMEM scratch — the canonical Pallas-TPU flash pattern.

GQA without KV duplication: the kv BlockSpec index map sends query head
``h`` to kv head ``h // R`` — grouping lives in the index map, not in
memory.

Causal masking: kv blocks strictly above the diagonal are skipped via
``pl.when`` (no wasted MXU work); the diagonal block is masked elementwise.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, bq, bk, causal, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: only kv blocks intersecting the lower triangle do work
    run = (ki * bk <= qi * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0]  # (bq, D)
        k = k_ref[0, 0]  # (bk, D)
        v = v_ref[0, 0]  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_bhsd(
    q, k, v, *, causal: bool = True, bq: int = 128, bk: int = 128, interpret: bool = True
):
    """q: (B, H, Sq, D); k/v: (B, K, Skv, D), H % K == 0 -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    R = H // K
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    grid = (B, H, Sq // bq, Skv // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, scale=1.0 / math.sqrt(D)
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // R, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
