"""Public flash-attention op in model layout (B, S, H, D)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128, bk: int = 128,
                    impl: str = "auto"):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D) -> (B, Sq, H, D)."""
    Sq, Skv = q.shape[1], k.shape[1]
    if impl == "ref" or (impl == "auto" and (Sq % bq or Skv % bk)):
        return flash_attention_ref(q, k, v, causal=causal)
    out = flash_attention_bhsd(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, bq=bq, bk=bk, interpret=jax.default_backend() != "tpu",
    )
    return out.swapaxes(1, 2)


KERNELS = {"flash_attention": flash_attention}
