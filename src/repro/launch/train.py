"""End-to-end training driver.

Wires every substrate together: futurized data pipeline (prefetch
overlap, paper Fig. 4), jit'd microbatched train step under the cell's
sharding rules, async checkpointing (paper Fig. 5), step monitor
(straggler detection), fail-stop resume.

With ``--workers N`` (N > 1), ``--elastic``, or a parcelport, the driver
routes through ``repro.training.elastic.ElasticTrainer`` instead: the
batch is sharded across workers, gradients come back as parcels, and a
worker death mid-run reshards over the survivors (DESIGN.md §16).
``--chaos SEED`` arms the fault injector with a deterministic mid-run
worker kill drawn from SEED — the CI recovery drill.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --smoke --steps 12 \
        --workers 4 --chaos 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig, smoke as smoke_cfg
from repro.data.pipeline import Pipeline, SyntheticTokens
from repro.distribution.recipes import plan_for
from repro.distribution.sharding import axis_rules
from repro.fault.monitor import StepMonitor
from repro.models import get_model
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def train(
    arch: str = "olmo-1b",
    *,
    use_smoke: bool = True,
    steps: int = 20,
    batch: int = 8,
    seq: int = 64,
    lr: float = 3e-4,
    ckpt_dir: "str | None" = None,
    ckpt_every: int = 10,
    resume: bool = False,
    mesh=None,
    rules: "dict | None" = None,
    log_every: int = 1,
    seed: int = 0,
    schedule_total: "int | None" = None,
    workers: int = 1,
    elastic: bool = False,
    port=None,
    chaos: "int | None" = None,
    grad_compression: bool = False,
) -> dict:
    if elastic or workers > 1 or port is not None:
        return _train_elastic(
            arch,
            use_smoke=use_smoke,
            steps=steps,
            batch=batch,
            seq=seq,
            lr=lr,
            ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every,
            resume=resume,
            log_every=log_every,
            seed=seed,
            schedule_total=schedule_total,
            workers=workers,
            port=port,
            chaos=chaos,
            grad_compression=grad_compression,
        )
    cfg = smoke_cfg(get_config(arch)) if use_smoke else get_config(arch)
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch, kind="train")
    plan = plan_for(cfg, shape)
    if batch < 2 * plan.num_microbatches:
        from dataclasses import replace

        plan = replace(plan, num_microbatches=1)
    horizon = schedule_total or steps  # LR schedule horizon survives restarts
    opt_cfg = OptConfig(lr=lr, warmup_steps=min(100, horizon // 10 + 1), total_steps=horizon)

    m = get_model(cfg)
    step_fn = make_train_step(cfg, shape, opt_cfg, plan)
    if mesh is not None:
        ctx = axis_rules(rules or plan.rules, mesh)
    else:
        from contextlib import nullcontext

        ctx = nullcontext()

    with ctx:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        params = m.init(cfg, jax.random.key(seed))
        opt_state = init_opt_state(params)

        start_step = 0
        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        if resume and mgr and mgr.latest_step() is not None:
            (params, opt_state), extra = mgr.restore((params, opt_state))
            start_step = extra.get("step", mgr.latest_step())
            cursor = extra.get("cursor", start_step)
        else:
            cursor = 0

        source = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)
        pipe = Pipeline(source, start=cursor, depth=2)
        monitor = StepMonitor()

        losses = []
        ckpt_futs = []
        try:
            for step in range(start_step, steps):
                t0 = time.time()
                idx, dev_batch = pipe.get()  # overlapped host->device feed
                params, opt_state, metrics = jit_step(params, opt_state, dev_batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                monitor.record(step, dt)
                losses.append(loss)
                if log_every and step % log_every == 0:
                    print(
                        f"step {step:5d} loss {loss:8.4f} gnorm {float(metrics['gnorm']):7.3f} "
                        f"lr {float(metrics['lr']):.2e} {dt * 1000:7.1f} ms",
                        flush=True,
                    )
                if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
                    # async save (Fig. 5 pattern): training continues while the
                    # writer thread serializes
                    ckpt_futs.append(
                        mgr.save_async(
                            step + 1,
                            (params, opt_state),
                            extra={"step": step + 1, "cursor": pipe.state()["cursor"]},
                        )
                    )
        finally:
            # Crash safety: a mid-loop failure must not abandon the writer
            # thread mid-serialization or leave prefetch batches in flight —
            # settle both before the exception propagates.
            pipe.close()
            for f in ckpt_futs:
                try:
                    f.wait()
                except Exception:  # noqa: BLE001 - teardown is best-effort
                    pass
            if mgr:
                mgr.wait()
        return {
            "losses": losses,
            "final_loss": losses[-1] if losses else float("nan"),
            "stragglers": len(monitor.events),
            "params": params,
            "opt_state": opt_state,
        }


def _train_elastic(
    arch: str,
    *,
    use_smoke: bool,
    steps: int,
    batch: int,
    seq: int,
    lr: float,
    ckpt_dir: "str | None",
    ckpt_every: int,
    resume: bool,
    log_every: int,
    seed: int,
    schedule_total: "int | None",
    workers: int,
    port,
    chaos: "int | None",
    grad_compression: bool,
) -> dict:
    """Elastic data-parallel route (DESIGN.md §16).  ``chaos`` arms a
    deterministic mid-run worker kill: the run must complete anyway, with
    the post-kill loss curve bit-identical to a clean survivor-count run
    from the same state (the property CI drills)."""
    from repro.fault.inject import FaultInjector
    from repro.training.elastic import ElasticTrainer

    trainer = ElasticTrainer(
        arch,
        use_smoke=use_smoke,
        batch=batch,
        seq=seq,
        lr=lr,
        seed=seed,
        workers=workers,
        port=port,
        grad_compression=grad_compression,
        total_steps=schedule_total or steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every,
        resume=resume,
    )
    try:
        if chaos is not None:
            inj = FaultInjector(seed=int(chaos))
            kill_after, victim = inj.plan_kill(steps - trainer.cursor, trainer.workers)
            inj.kill_at_step(victim, trainer.cursor + kill_after)
        out = trainer.run(max(0, steps - trainer.cursor), log_every=log_every)
    finally:
        trainer.close()
    out["recoveries"] = [e for e in trainer.events if e[0] == "death"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1, help="data-parallel workers (>1 = elastic)")
    ap.add_argument("--elastic", action="store_true", help="elastic route even with 1 worker")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="arm the fault injector: kill a seeded-random worker mid-run")
    ap.add_argument("--grad-compression", action="store_true",
                    help="int8 stochastic-rounding gradient parcels")
    args = ap.parse_args()

    out = train(
        args.arch,
        use_smoke=args.smoke,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        seed=args.seed,
        workers=args.workers,
        elastic=args.elastic,
        chaos=args.chaos,
        grad_compression=args.grad_compression,
    )
    print(f"final loss: {out['final_loss']:.4f}  stragglers: {out['stragglers']}")
    for ev in out.get("recoveries", []):
        print(f"recovered: worker {ev[2]} died at step {ev[1]}, resharded over survivors")


if __name__ == "__main__":
    main()
