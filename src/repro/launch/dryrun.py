"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — using
ShapeDtypeStruct stand-ins (no allocation), then records
``memory_analysis()`` / ``cost_analysis()`` / loop-aware HLO roofline
terms to ``results/dryrun/*.json``.

Usage:
    python -m repro.launch.dryrun --all                # single-pod, all cells
    python -m repro.launch.dryrun --all --multi-pod
    python -m repro.launch.dryrun --cell deepseek-67b:train_4k
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import hlo_analysis  # noqa: E402
from repro.configs import cells, get_config, get_shape  # noqa: E402
from repro.distribution.recipes import plan_for  # noqa: E402
from repro.distribution.sharding import axis_rules, spec_for, tree_sharding  # noqa: E402
from repro.models import batch_logical_specs, get_model, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.serving.serve_step import make_prefill, make_serve_step  # noqa: E402
from repro.training.optimizer import OptConfig  # noqa: E402
from repro.training.train_step import make_init, make_train_step  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_record(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": repr(e)}


def _cost_record(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        ca = ca if isinstance(ca, dict) else ca[0]
        return {
            "flops": float(ca.get("flops", -1)),
            "bytes_accessed": float(ca.get("bytes accessed", -1)),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": repr(e)}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False, mesh=None, plan=None):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan if plan is not None else plan_for(cfg, shape, multi_pod=multi_pod)
    if plan.moe_groups is not None and cfg.moe is not None:
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=plan.moe_groups)
        )
    m = get_model(cfg)
    rules = plan.rules
    kind = shape.kind

    t0 = time.time()
    batch_specs = input_specs(cfg, shape)
    blog = batch_logical_specs(cfg, shape)
    batch_sh = {
        k: NamedSharding(mesh, spec_for(blog[k], rules, shape=v.shape, mesh=mesh))
        for k, v in batch_specs.items()
    }
    pspecs = m.param_specs(cfg)
    repl = NamedSharding(mesh, P())

    if kind == "train":
        opt_cfg = OptConfig()
        init = make_init(cfg, opt_cfg, dtype=jnp.float32)
        params_s, opt_s = jax.eval_shape(init, jax.random.key(0))
        param_sh = tree_sharding(mesh, pspecs, rules, params_s)
        opt_sh = {
            "m": tree_sharding(mesh, pspecs, rules, opt_s["m"]),
            "v": tree_sharding(mesh, pspecs, rules, opt_s["v"]),
            "step": repl,
        }
        step = make_train_step(cfg, shape, opt_cfg, plan)
        with axis_rules(rules, mesh):
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_s, opt_s, batch_specs)
        fn_name = "train_step"
    elif kind == "prefill":
        params_s = jax.eval_shape(lambda k: m.init(cfg, k, jnp.bfloat16), jax.random.key(0))
        param_sh = tree_sharding(mesh, pspecs, rules, params_s)
        prefill = make_prefill(cfg, plan)
        with axis_rules(rules, mesh):
            lowered = jax.jit(
                prefill, in_shardings=(param_sh, batch_sh), out_shardings=None
            ).lower(params_s, batch_specs)
        fn_name = "prefill"
    else:  # decode
        params_s = jax.eval_shape(lambda k: m.init(cfg, k, jnp.bfloat16), jax.random.key(0))
        param_sh = tree_sharding(mesh, pspecs, rules, params_s)
        cache_s = jax.eval_shape(
            lambda: m.init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        cache_sh = tree_sharding(mesh, m.cache_specs(cfg), rules, cache_s)
        tok_s = batch_specs["tokens"]
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        serve = make_serve_step(cfg, plan)
        with axis_rules(rules, mesh):
            lowered = jax.jit(
                serve,
                in_shardings=(param_sh, cache_sh, batch_sh["tokens"], repl),
                out_shardings=(None, None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_s, cache_s, tok_s, pos_s)
        fn_name = "serve_step"

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    t0 = time.time()
    hlo_text = compiled.as_text()
    hlo = hlo_analysis.analyze(hlo_text)
    t_analyze = time.time() - t0

    # store compressed HLO so analyses can be re-run without recompiling
    try:
        import zstandard

        hlo_dir = RESULTS_DIR.parent / "hlo"
        hlo_dir.mkdir(parents=True, exist_ok=True)
        tag = "multipod" if mesh.devices.size > 256 else "singlepod"
        (hlo_dir / f"{arch}__{shape_name}__{tag}.hlo.zst").write_bytes(
            zstandard.ZstdCompressor(level=3).compress(hlo_text.encode())
        )
    except Exception:  # noqa: BLE001 - storage is best-effort
        pass

    n_dev = mesh.devices.size
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "fn": fn_name,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "devices": int(n_dev),
        "plan": {
            "remat": plan.remat,
            "q_block": plan.q_block,
            "num_microbatches": plan.num_microbatches,
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory": _mem_record(compiled),
        "cost_analysis": _cost_record(compiled),
        "hlo": hlo,
        "timing_s": {"lower": t_lower, "compile": t_compile, "analyze": t_analyze},
    }
    return record


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> Path:
    tag = "multipod" if multi_pod else "singlepod"
    return RESULTS_DIR / f"{arch}__{shape_name}__{tag}.json"


def run_cell(arch, shape_name, multi_pod, mesh=None, force=False) -> dict:
    path = cell_path(arch, shape_name, multi_pod)
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if "error" not in rec:
            print(f"[cached] {arch}:{shape_name} ({'multi' if multi_pod else 'single'})")
            return rec
    print(f"[lower ] {arch}:{shape_name} ({'multi' if multi_pod else 'single'}) ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, mesh=mesh)
        mem = rec["memory"]
        tot = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 1e9
        print(
            f"[ok    ] {arch}:{shape_name} compile={rec['timing_s']['compile']:.1f}s "
            f"mem/dev={tot:.2f}GB colls={sum(rec['hlo']['collective_counts'].values())}",
            flush=True,
        )
    except Exception:  # noqa: BLE001
        rec = {
            "arch": arch,
            "shape": shape_name,
            "multi_pod": multi_pod,
            "error": traceback.format_exc(limit=20),
        }
        print(f"[FAIL  ] {arch}:{shape_name}\n{rec['error']}", flush=True)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    return rec


def reanalyze_all() -> int:
    """Recompute rec['hlo'] from stored HLO texts (no recompilation)."""
    import zstandard

    hlo_dir = RESULTS_DIR.parent / "hlo"
    n = 0
    for z in sorted(hlo_dir.glob("*.hlo.zst")):
        stem = z.name[: -len(".hlo.zst")]
        rec_path = RESULTS_DIR / f"{stem}.json"
        if not rec_path.exists():
            continue
        rec = json.loads(rec_path.read_text())
        text = zstandard.ZstdDecompressor().decompress(z.read_bytes()).decode()
        rec["hlo"] = hlo_analysis.analyze(text)
        rec_path.write_text(json.dumps(rec, indent=1))
        n += 1
        print(f"[reanalyzed] {stem}")
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cell", action="append", default=[], help="arch:shape")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true", help="recompute hlo terms from stored texts")
    args = ap.parse_args()

    if args.reanalyze:
        n = reanalyze_all()
        print(f"reanalyzed {n} records")
        return

    todo = []
    if args.all:
        todo = cells()
    elif args.arch:
        todo = [(a, s) for a, s in cells() if a == args.arch]
    for c in args.cell:
        a, s = c.split(":")
        todo.append((a, s))
    if not todo:
        ap.error("nothing to do; pass --all or --cell arch:shape")

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch, shape_name in todo:
            rec = run_cell(arch, shape_name, mp, mesh=mesh, force=args.force)
            failures += 1 if "error" in rec else 0
    print(f"done: {len(todo) * len(meshes)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
