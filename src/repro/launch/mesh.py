"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state.  Single pod: 16x16 = 256 chips, axes (data, model).  Multi-pod:
2x16x16 = 512 chips, axes (pod, data, model) — the ``pod`` axis composes
with ``data`` for batch/FSDP sharding; cross-pod traffic is the gradient/
FSDP all-reduce over DCI.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    # jax.sharding.AxisType landed in newer jax; older versions default all
    # axes to Auto anyway, so omit the kwarg when it doesn't exist.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices this host exposes —
    used by examples/tests; same axis names as production."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_type_kwargs(2))
