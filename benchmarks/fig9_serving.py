"""Fig. 9 (extension): continuous-batching + paged serving (DESIGN.md §12, §15).

The ``RequestEngine`` exists to keep accelerators utilized under many
small concurrent requests: per-request dispatch overhead (queue hop,
device_put, executable lookup) is paid once per MICRO-BATCH instead of
once per request.  This benchmark drives identical request streams two
ways:

* ``serial``  — per-request serving: each request runs alone through
  ``Program.run`` and is waited on before the next starts (the no-engine
  baseline every request-level server starts from).
* ``batched`` — all requests submitted concurrently to a
  ``RequestEngine`` (max_batch=8): the engine assembles micro-batches,
  pads to buckets, replays the captured step on an engine stream and
  slices per-request results.

Rows report seconds per request (us_per_call column), with requests/s,
latency p50/p99 and the engine's padded-row waste in the derived field;
a forced-8-device row shows the same stream spread over a fleet by
``least_loaded``.

The ``paged`` rows drive the §15 stack end to end: two toy GQA LMs (a
multi-model fleet) served by ``PagedServeEngine`` — prompts prefilled in
token-budgeted groups, KV paged into per-device pools, one decode lane
per device stepping its residents continuously over page tables.  Rows
report sequences/s, token-latency p99 against the serving SLO
(``REPRO_SERVING_SLO_MS``, default 250), time-to-first-token p99, and
padding waste; generated tokens are asserted identical between the
1-device and 8-device fleets.

**Occupancy model** (the fig6 pattern): a CPU-only runner has one set of
cores behind all forced host devices, so 8 "devices" can never genuinely
beat 1 on raw compute.  As in fig6, each decode step therefore *occupies
its device's real ops-queue lane* for ``rows x _TOK_S`` (a ``time.sleep``
submitted through the lane FIFO — it releases the GIL, so co-located
engines serialize on their shared device while distinct devices overlap
exactly like real hardware), and the real jitted paged-attention math
runs for correctness on top.  Everything the runtime is responsible for —
admission, prefill grouping, page alloc/free, table builds, placement,
warm-shape reuse, donation — is exercised for real; only the per-row
device clock is synthetic.

The ``zoo`` rows replace the toys with REAL zoo architectures (smoke'd):
a dense transformer and an SSM LM served as one fleet through
``PagedServeEngine.from_config`` (DESIGN.md §17) — multi-layer folded
pages, resident recurrent state, host-side sampling — under the same
occupancy model, with 1-device vs 8-device token streams asserted
bit-identical.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=8``
and parses the CSV it prints (the fig6 pattern).  Results land in
``BENCH_serving.json`` via ``benchmarks/run.py``; CI asserts the batched
row beats the serial row and holds >= 0.95x of the 1-device engine's
requests/s when spread over the fleet, that the paged and zoo 8-device
fleets meet or beat their single-device rows on sequences/s, and that
their token p99 is inside the SLO.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_cpu_multi_thread_eigen=false "
                           + os.environ.get("XLA_FLAGS", ""))
import time

import numpy as np
import jax
import jax.numpy as jnp
from repro.core import Scheduler, get_all_devices, wait_all
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.partition_map.ref import partition_map_ref
from repro.serving import LanePolicy, PagedKVCache, PagedServeEngine, PageSpec, RequestEngine

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
N = 256
LOOPS = 2 if quick else 4
R = 32 if quick else 64
REPS = 2 if quick else 3

def step(x):
    def body(i, v):
        return partition_map_ref(v) * 0.5 + v * 0.5
    return jax.lax.fori_loop(0, LOOPS, body, x)

devices = get_all_devices(1, 0).get()
assert len(devices) == 8, devices
dev = devices[0]
rng = np.random.default_rng(0)
payloads = [rng.normal(size=(1, N)).astype(np.float32) for _ in range(R)]

def pct(lats, q):
    ls = sorted(lats)
    return ls[int(q * (len(ls) - 1))]

# --- serial: one request at a time through Program.run ----------------------
prog = dev.create_program({"step": step}, "fig9").get()
prog.run([payloads[0]], "step").get()  # warm the executable

def serial_pass():
    lats = []
    t0 = time.perf_counter()
    for p in payloads:
        t = time.perf_counter()
        prog.run([p], "step").get()
        lats.append(time.perf_counter() - t)
    return time.perf_counter() - t0, lats

serial_pass()
best_wall, best_lats = min((serial_pass() for _ in range(REPS)), key=lambda r: r[0])
ref = [np.asarray(prog.run([p], "step").get()) for p in payloads]
print(f"CSVROW,fig9/serving_serial_1dev,{best_wall / R * 1e6:.1f},"
      f"rps={R / best_wall:.1f};p50_ms={pct(best_lats, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(best_lats, 0.99) * 1e3:.2f};requests={R}")

# --- batched: concurrent submission through the RequestEngine ----------------
def make_batched(sched, name):
    eng = RequestEngine(step, max_batch=8, max_delay_s=0.002, max_queue=4 * R,
                        scheduler=sched, name=name)
    # Warm every bucket route the placement will actually use: jit
    # caches key on (rows x device); sticky placement pins a stream, so
    # either the fleet is covered within a few passes (spread policies)
    # or it never will be (a held home) — 16 passes bounds both.
    for _ in range(16):
        wait_all([eng.submit(p) for p in payloads])
        if len(sched.stats()) >= len(sched.devices()):
            break
    return eng

def batched_rep(eng):
    t0 = time.perf_counter()
    stamped = []
    for p in payloads:
        ts = time.perf_counter()
        f = eng.submit(p)
        # client-observed latency: submit -> slice resolution
        stamped.append(f.then(
            lambda v, ts=ts: (time.perf_counter() - ts, v), executor="inline"
        ))
    wait_all(stamped)
    return time.perf_counter() - t0, stamped

sched1 = Scheduler([dev], policy="least_loaded")
sched8 = Scheduler(devices, policy="least_loaded")
eng1 = make_batched(sched1, "fig9-1dev")
eng8 = make_batched(sched8, "fig9-8dev")
BREPS = 5 if quick else 6
best1 = best8 = None
try:
    # Interleaved reps: the CI gate checks the 8dev/1dev RATIO, so both
    # fleets must sample the same noise environment — two disjoint
    # measurement windows would put the ratio at the mercy of machine-
    # load drift between them.
    for _ in range(BREPS):
        w, s = batched_rep(eng1)
        if best1 is None or w < best1[0]:
            best1 = (w, s)
        w, s = batched_rep(eng8)
        if best8 is None or w < best8[0]:
            best8 = (w, s)
    for label, (wall, stamped), eng, sched in (
            ("1dev", best1, eng1, sched1), ("8dev", best8, eng8, sched8)):
        lats = []
        for want, f in zip(ref, stamped):
            lat, got = f.get()
            lats.append(lat)
            assert got.dtype == want.dtype and np.array_equal(got, want), "diverged"
        m = eng.metrics()
        spread = f"spread={len(sched.stats())};" if label == "8dev" else ""
        print(f"CSVROW,fig9/serving_batched_{label},{wall / R * 1e6:.1f},"
              f"rps={R / wall:.1f};p50_ms={pct(lats, 0.5) * 1e3:.2f};"
              f"p99_ms={pct(lats, 0.99) * 1e3:.2f};"
              f"mean_batch={m['mean_batch_rows']:.1f};"
              f"waste={m['padding_waste']:.3f};{spread}requests={R}")
finally:
    eng1.close()
    eng8.close()

# --- paged: prefill/decode disaggregation over paged KV (DESIGN.md S15) ------
PAGE = 16
MAXLEN = 128
_TOK_S = 10e-3 if quick else 4e-3  # modeled device-s per decode row (docstring)
_PRE_TOK_S = 5e-5    # modeled device-seconds per prefill prompt token
S = 32 if quick else 64  # /2 models: pow-2 seqs per engine = exact warm shape
NEW = 12 if quick else 32
SLO_MS = float(os.environ.get("REPRO_SERVING_SLO_MS", "250"))

_by_jax = {d.jax_device: d for d in devices}

def _occupy(jdev, seconds):
    # Hold the device's REAL lane FIFO for the modeled device time:
    # engines sharing a device serialize here, distinct devices overlap
    # (sleep releases the GIL) — exactly the fig6 occupancy model.
    _by_jax[jdev].ops_queue.submit(lambda: time.sleep(seconds)).get()

def _dev_of(a):
    d = getattr(a, "device", None)
    if callable(d):
        d = d()
    if d is None:
        d = next(iter(a.devices()))
    return d

def make_paged_lm(seed, V, Dm, H, K):
    D = Dm // H
    r = np.random.default_rng(seed)
    s = 1.0 / np.sqrt(Dm)
    emb = jnp.asarray(r.normal(size=(V, Dm)).astype(np.float32) * s)
    wq = jnp.asarray(r.normal(size=(Dm, H * D)).astype(np.float32) * s)
    wk = jnp.asarray(r.normal(size=(Dm, K * D)).astype(np.float32) * s)
    wv = jnp.asarray(r.normal(size=(Dm, K * D)).astype(np.float32) * s)
    wo = jnp.asarray(r.normal(size=(H * D, Dm)).astype(np.float32) * s)
    wu = jnp.asarray(r.normal(size=(Dm, V)).astype(np.float32) * s)

    @jax.jit
    def prefill_core(tokens):
        x = emb[tokens]                               # (B, T, Dm)
        B, T, _ = x.shape
        k = (x @ wk).reshape(B, T, K, D)
        v = (x @ wv).reshape(B, T, K, D)
        q = (x[:, -1] @ wq).reshape(B, K, H // K, D)  # GQA: grouped heads
        sc = jnp.einsum("bkrd,btkd->bkrt", q, k) / np.sqrt(D)
        o = jnp.einsum("bkrt,btkd->bkrd", jax.nn.softmax(sc, axis=-1), v)
        logits = (o.reshape(B, H * D) @ wo) @ wu
        return k[:, None], v[:, None], jnp.argmax(logits, -1).astype(jnp.int32)

    @jax.jit
    def decode_core(kp, vp, tokens, positions, tables, lengths):
        x = emb[tokens]                               # (B, Dm)
        b = tokens.shape[0]
        q = (x @ wq).reshape(b, H, D)
        k = (x @ wk).reshape(b, K, D)
        v = (x @ wv).reshape(b, K, D)
        page = tables[jnp.arange(b), positions // PAGE]
        kp = kp.at[0, page, positions % PAGE].set(k)  # scatter the new token
        vp = vp.at[0, page, positions % PAGE].set(v)
        o = paged_attention_ref(q, kp[0], vp[0], tables, lengths + 1)
        logits = (o.reshape(b, H * D) @ wo) @ wu
        return kp, vp, jnp.argmax(logits, -1).astype(jnp.int32)
    decode_core = jax.jit(decode_core, donate_argnums=(0, 1))

    def prefill_fn(tokens):
        _occupy(devices[0].jax_device, tokens.shape[0] * tokens.shape[1] * _PRE_TOK_S)
        return prefill_core(tokens)

    def decode_fn(kp, vp, tokens, positions, tables, lengths):
        _occupy(_dev_of(kp), tokens.shape[0] * _TOK_S)
        return decode_core(kp, vp, tokens, positions, tables, lengths)

    return prefill_fn, decode_fn, decode_core, K, D

# Multi-model fleet: two GQA LMs of different sizes share the scheduler.
# Built ONCE so both fleet labels hit the same jit caches.
MODELS = ((0, 512, 128, 4, 2), (1, 256, 64, 4, 2))
LMS = [make_paged_lm(*m) for m in MODELS]
POOL_PAGES = 192
plens = [4, 8, 16]
work = sorted(
    [(i % 2, plens[int(v)], NEW) for i, v in enumerate(rng.integers(0, 3, size=S))],
    key=lambda t: (t[0], t[1]))  # sorted: deterministic prefill groups

def paged_pass(devs, label):
    sched = Scheduler(devs, policy="least_loaded")
    # Palette of decode row counts this fleet can see: steady state is
    # seqs-per-engine split over len(devs) lanes; 4x headroom covers skew.
    avg = max(1, -(-(S // 2) // len(devs)))
    shapes = tuple(b for b in (1, 2, 4, 8, 16, 32, 64)
                   if b <= min(S // 2, 4 * avg))
    engines = []
    for (seed, *_), (pf, df, core, kh, hd) in zip(MODELS, LMS):
        kv = PagedKVCache(PageSpec(1, PAGE, kh, hd), devices=devs,
                          pool_pages=POOL_PAGES)
        engines.append(PagedServeEngine(
            kv, pf, df, max_seq_len=MAXLEN, scheduler=sched,
            prefill=LanePolicy(max_batch=16, max_delay_s=0.05, token_budget=1024),
            decode=LanePolicy(max_batch=64, max_delay_s=0.05),
            decode_shapes=shapes,
            name=f"fig9-paged-{label}-m{seed}"))

    # Prewarm every palette shape on every device OUTSIDE the measured
    # window: jit caches key on (rows x device), so a first use inside a
    # measured rep would charge a ~100ms compile to some token's p99.
    M = MAXLEN // PAGE
    for pf, df, core, kh, hd in LMS:
        for d in devs:
            sh = (1, POOL_PAGES, PAGE, kh, hd)
            kz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            vz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            for b in shapes:
                kz, vz, _ = core(kz, vz, np.zeros(b, np.int32),
                                 np.zeros(b, np.int32),
                                 np.zeros((b, M), np.int32),
                                 np.zeros(b, np.int32))
            jax.block_until_ready((kz, vz))

    def one_pass():
        t0 = time.perf_counter()
        futs = [engines[mi].submit(np.arange(plen, dtype=np.int32) % 100, nnew)
                for mi, plen, nnew in work]
        outs = [np.asarray(f.get()) for f in futs]
        return outs, time.perf_counter() - t0

    one_pass()  # warm: compiles the prefill groups and warm decode shapes
    best = None
    for _ in range(REPS):
        for e in engines:
            e.reset_metrics()
        outs, wall = one_pass()
        ms = [e.metrics() for e in engines]
        if best is None or wall < best[1]:
            best = (outs, wall, ms)
    for e in engines:
        e.close()
    outs, wall, ms = best
    rows = sum(m["rows"] for m in ms)
    padded = sum(m["padded_rows"] for m in ms)
    print(f"CSVROW,fig9/serving_paged_{label},{wall / S * 1e6:.1f},"
          f"seqs_per_s={S / wall:.2f};"
          f"p99_tok_ms={max(m['token_latency_p99_s'] for m in ms) * 1e3:.1f};"
          f"ttft_p99_ms={max(m['ttft_p99_s'] for m in ms) * 1e3:.1f};"
          f"waste={(padded / rows) if rows else 0.0:.3f};"
          f"slo_ms={SLO_MS:.0f};migrations={sum(m['migrations'] for m in ms)};"
          f"sequences={S};new_tokens={NEW}")
    return outs

out1 = paged_pass(devices[:1], "1dev")
out8 = paged_pass(devices, "8dev")
# Same prompts, same models, two fleets: greedy tokens must agree bit-for-bit.
assert all(np.array_equal(a, b) for a, b in zip(out1, out8)), "paged fleets diverged"

# --- zoo: real architectures through the paged engine (DESIGN.md S17) --------
# Two model-zoo families (dense transformer + SSM) served as ONE fleet by
# ``PagedServeEngine.from_config`` — the smoke'd real models, not toys:
# multi-layer folded pages, resident recurrent state, host-side sampling.
# The occupancy model wraps the zoo decode step exactly as above.
from repro.configs import get_config, smoke
from repro.models.model import get_model
from repro.serving import SamplingParams

S_ZOO = 16 if quick else 24
NEW_ZOO = 6 if quick else 10
ZOO = ("olmo-1b", "mamba2-130m")
ZOO_CFGS = [smoke(get_config(n)) for n in ZOO]
ZOO_PARAMS = [get_model(c).init(c, jax.random.PRNGKey(i))
              for i, c in enumerate(ZOO_CFGS)]
zoo_work = sorted(
    [(i % 2, (5, 9, 17)[int(v)], NEW_ZOO)
     for i, v in enumerate(rng.integers(0, 3, size=S_ZOO))],
    key=lambda t: (t[0], t[1]))  # sorted: deterministic prefill groups

ZOO_POOL = 96
ZOO_SHAPES = (1, 2, 4, 8)

def zoo_pass(devs, label):
    sched = Scheduler(devs, policy="least_loaded")
    engines, inners = [], []
    for i, (cfg, params) in enumerate(zip(ZOO_CFGS, ZOO_PARAMS)):
        eng = PagedServeEngine.from_config(
            cfg, params=params, devices=devs, max_seq_len=48,
            pool_pages=ZOO_POOL, scheduler=sched,
            prefill=LanePolicy(max_batch=8, max_delay_s=0.02, token_budget=512),
            decode=LanePolicy(max_batch=8, max_delay_s=0.02),
            decode_shapes=ZOO_SHAPES,
            name=f"fig9-zoo-{label}-m{i}")
        inner = eng.decode_fn
        def wrapped(ks, vs, state, tokens, positions, tables, lengths, _in=inner):
            _occupy(_dev_of(ks), tokens.shape[0] * _TOK_S)
            return _in(ks, vs, state, tokens, positions, tables, lengths)
        eng.decode_fn = wrapped
        engines.append(eng)
        inners.append(inner)

    # Prewarm every palette row count on every device OUTSIDE the measured
    # window, exactly as paged_pass does: the decode jit keys on
    # (rows x device), and a real-model compile inside a measured rep
    # would charge ~1s to some token's p99.  A throwaway 1-row prefill
    # yields the family's resident-state row template (None for pure
    # transformers); zero slabs of the pool's geometry stand in for the
    # real pools (page 0 is the scatter sink — it is the reserved
    # sentinel, never read back).
    for eng, inner in zip(engines, inners):
        spec = eng.kv.spec
        st = eng.prefill_fn(np.ones((1, 4), np.int32), None)[2]
        row = (None if st is None
               else jax.tree_util.tree_map(lambda a: np.asarray(a)[0], st))
        sh = (spec.layers, ZOO_POOL, spec.page_size, spec.kv_heads,
              spec.head_dim)
        for d in devs:
            kz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            vz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            for b in ZOO_SHAPES:
                stb = (None if row is None else jax.tree_util.tree_map(
                    lambda a, _b=b: np.stack([a] * _b), row))
                kz, vz, _, _ = inner(kz, vz, stb, np.zeros(b, np.int32),
                                     np.zeros(b, np.int32),
                                     np.zeros((b, eng.max_pages), np.int32),
                                     np.zeros(b, np.int32))
            jax.block_until_ready((kz, vz))

    def one_pass():
        t0 = time.perf_counter()
        futs = [engines[mi].submit(
                    np.arange(plen, dtype=np.int32) % (ZOO_CFGS[mi].vocab_size - 1) + 1,
                    nnew)
                for mi, plen, nnew in zoo_work]
        outs = [np.asarray(f.get()) for f in futs]
        return outs, time.perf_counter() - t0

    one_pass()  # warm: prefill groups + decode palette compile here
    best = None
    for _ in range(REPS):
        for e in engines:
            e.reset_metrics()
        outs, wall = one_pass()
        ms = [e.metrics() for e in engines]
        if best is None or wall < best[1]:
            best = (outs, wall, ms)
    for e in engines:
        e.close()
    outs, wall, ms = best
    rows = sum(m["rows"] for m in ms)
    padded = sum(m["padded_rows"] for m in ms)
    print(f"CSVROW,fig9/serving_zoo_{label},{wall / S_ZOO * 1e6:.1f},"
          f"seqs_per_s={S_ZOO / wall:.2f};"
          f"p99_tok_ms={max(m['token_latency_p99_s'] for m in ms) * 1e3:.1f};"
          f"ttft_p99_ms={max(m['ttft_p99_s'] for m in ms) * 1e3:.1f};"
          f"waste={(padded / rows) if rows else 0.0:.3f};"
          f"slo_ms={SLO_MS:.0f};models={len(ZOO)};"
          f"sequences={S_ZOO};new_tokens={NEW_ZOO}")
    return outs

z1 = zoo_pass(devices[:1], "1dev")
z8 = zoo_pass(devices, "8dev")
# Real-model fleets must agree bit-for-bit too (greedy, per-row math).
assert all(np.array_equal(a, b) for a, b in zip(z1, z8)), "zoo fleets diverged"
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if len(rows) < 7 or proc.returncode != 0:
        rows.append(
            {"name": "fig9/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
