"""Fig. 9 (extension): continuous-batching + paged serving (DESIGN.md §12, §15).

The ``RequestEngine`` exists to keep accelerators utilized under many
small concurrent requests: per-request dispatch overhead (queue hop,
device_put, executable lookup) is paid once per MICRO-BATCH instead of
once per request.  This benchmark drives identical request streams two
ways:

* ``serial``  — per-request serving: each request runs alone through
  ``Program.run`` and is waited on before the next starts (the no-engine
  baseline every request-level server starts from).
* ``batched`` — all requests submitted concurrently to a
  ``RequestEngine`` (max_batch=8): the engine assembles micro-batches,
  pads to buckets, replays the captured step on an engine stream and
  slices per-request results.

Rows report seconds per request (us_per_call column), with requests/s,
latency p50/p99 and the engine's padded-row waste in the derived field;
a forced-8-device row shows the same stream spread over a fleet by
``least_loaded``.

The ``paged`` rows drive the §15 stack end to end: two toy GQA LMs (a
multi-model fleet) served by ``PagedServeEngine`` — prompts prefilled in
token-budgeted groups, KV paged into per-device pools, one decode lane
per device stepping its residents continuously over page tables.  Rows
report sequences/s, token-latency p99 against the serving SLO
(``REPRO_SERVING_SLO_MS``, default 250), time-to-first-token p99, and
padding waste; generated tokens are asserted identical between the
1-device and 8-device fleets.

**Occupancy model** (the fig6 pattern): a CPU-only runner has one set of
cores behind all forced host devices, so 8 "devices" can never genuinely
beat 1 on raw compute.  As in fig6, each decode step therefore *occupies
its device's real ops-queue lane* for ``rows x _TOK_S`` (a ``time.sleep``
submitted through the lane FIFO — it releases the GIL, so co-located
engines serialize on their shared device while distinct devices overlap
exactly like real hardware), and the real jitted paged-attention math
runs for correctness on top.  Everything the runtime is responsible for —
admission, prefill grouping, page alloc/free, table builds, placement,
warm-shape reuse, donation — is exercised for real; only the per-row
device clock is synthetic.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=8``
and parses the CSV it prints (the fig6 pattern).  Results land in
``BENCH_serving.json`` via ``benchmarks/run.py``; CI asserts the batched
row beats the serial row, that the paged 8-device fleet meets or beats
the paged single device on sequences/s, and that its token p99 is inside
the SLO.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_cpu_multi_thread_eigen=false "
                           + os.environ.get("XLA_FLAGS", ""))
import time

import numpy as np
import jax
import jax.numpy as jnp
from repro.core import Scheduler, get_all_devices, wait_all
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.partition_map.ref import partition_map_ref
from repro.serving import LanePolicy, PagedKVCache, PagedServeEngine, PageSpec, RequestEngine

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
N = 256
LOOPS = 2 if quick else 4
R = 32 if quick else 64
REPS = 2 if quick else 3

def step(x):
    def body(i, v):
        return partition_map_ref(v) * 0.5 + v * 0.5
    return jax.lax.fori_loop(0, LOOPS, body, x)

devices = get_all_devices(1, 0).get()
assert len(devices) == 8, devices
dev = devices[0]
rng = np.random.default_rng(0)
payloads = [rng.normal(size=(1, N)).astype(np.float32) for _ in range(R)]

def pct(lats, q):
    ls = sorted(lats)
    return ls[int(q * (len(ls) - 1))]

# --- serial: one request at a time through Program.run ----------------------
prog = dev.create_program({"step": step}, "fig9").get()
prog.run([payloads[0]], "step").get()  # warm the executable

def serial_pass():
    lats = []
    t0 = time.perf_counter()
    for p in payloads:
        t = time.perf_counter()
        prog.run([p], "step").get()
        lats.append(time.perf_counter() - t)
    return time.perf_counter() - t0, lats

serial_pass()
best_wall, best_lats = min((serial_pass() for _ in range(REPS)), key=lambda r: r[0])
ref = [np.asarray(prog.run([p], "step").get()) for p in payloads]
print(f"CSVROW,fig9/serving_serial_1dev,{best_wall / R * 1e6:.1f},"
      f"rps={R / best_wall:.1f};p50_ms={pct(best_lats, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(best_lats, 0.99) * 1e3:.2f};requests={R}")

# --- batched: concurrent submission through the RequestEngine ----------------
def engine_pass(sched, name):
    eng = RequestEngine(step, max_batch=8, max_delay_s=0.002, max_queue=4 * R,
                        scheduler=sched, name=name)
    try:
        wait_all([eng.submit(p) for p in payloads])  # warm every bucket route
        best = None
        for _ in range(REPS):
            t0 = time.perf_counter()
            stamped = []
            for p in payloads:
                ts = time.perf_counter()
                f = eng.submit(p)
                # client-observed latency: submit -> slice resolution
                stamped.append(f.then(
                    lambda v, ts=ts: (time.perf_counter() - ts, v), executor="inline"
                ))
            wait_all(stamped)
            wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, stamped)
        wall, stamped = best
        lats = []
        for want, f in zip(ref, stamped):
            lat, got = f.get()
            lats.append(lat)
            assert got.dtype == want.dtype and np.array_equal(got, want), "diverged"
        return wall, lats, eng.metrics()
    finally:
        eng.close()

wall, lats, m = engine_pass(Scheduler([dev], policy="least_loaded"), "fig9-1dev")
print(f"CSVROW,fig9/serving_batched_1dev,{wall / R * 1e6:.1f},"
      f"rps={R / wall:.1f};p50_ms={pct(lats, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(lats, 0.99) * 1e3:.2f};"
      f"mean_batch={m['mean_batch_rows']:.1f};waste={m['padding_waste']:.3f};requests={R}")

sched8 = Scheduler(devices, policy="least_loaded")
wall8, lats8, m8 = engine_pass(sched8, "fig9-8dev")
print(f"CSVROW,fig9/serving_batched_8dev,{wall8 / R * 1e6:.1f},"
      f"rps={R / wall8:.1f};p50_ms={pct(lats8, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(lats8, 0.99) * 1e3:.2f};"
      f"mean_batch={m8['mean_batch_rows']:.1f};waste={m8['padding_waste']:.3f};"
      f"spread={len(sched8.stats())};requests={R}"
)

# --- paged: prefill/decode disaggregation over paged KV (DESIGN.md S15) ------
PAGE = 16
MAXLEN = 128
_TOK_S = 10e-3 if quick else 4e-3  # modeled device-s per decode row (docstring)
_PRE_TOK_S = 5e-5    # modeled device-seconds per prefill prompt token
S = 32 if quick else 64  # /2 models: pow-2 seqs per engine = exact warm shape
NEW = 12 if quick else 32
SLO_MS = float(os.environ.get("REPRO_SERVING_SLO_MS", "250"))

_by_jax = {d.jax_device: d for d in devices}

def _occupy(jdev, seconds):
    # Hold the device's REAL lane FIFO for the modeled device time:
    # engines sharing a device serialize here, distinct devices overlap
    # (sleep releases the GIL) — exactly the fig6 occupancy model.
    _by_jax[jdev].ops_queue.submit(lambda: time.sleep(seconds)).get()

def _dev_of(a):
    d = getattr(a, "device", None)
    if callable(d):
        d = d()
    if d is None:
        d = next(iter(a.devices()))
    return d

def make_paged_lm(seed, V, Dm, H, K):
    D = Dm // H
    r = np.random.default_rng(seed)
    s = 1.0 / np.sqrt(Dm)
    emb = jnp.asarray(r.normal(size=(V, Dm)).astype(np.float32) * s)
    wq = jnp.asarray(r.normal(size=(Dm, H * D)).astype(np.float32) * s)
    wk = jnp.asarray(r.normal(size=(Dm, K * D)).astype(np.float32) * s)
    wv = jnp.asarray(r.normal(size=(Dm, K * D)).astype(np.float32) * s)
    wo = jnp.asarray(r.normal(size=(H * D, Dm)).astype(np.float32) * s)
    wu = jnp.asarray(r.normal(size=(Dm, V)).astype(np.float32) * s)

    @jax.jit
    def prefill_core(tokens):
        x = emb[tokens]                               # (B, T, Dm)
        B, T, _ = x.shape
        k = (x @ wk).reshape(B, T, K, D)
        v = (x @ wv).reshape(B, T, K, D)
        q = (x[:, -1] @ wq).reshape(B, K, H // K, D)  # GQA: grouped heads
        sc = jnp.einsum("bkrd,btkd->bkrt", q, k) / np.sqrt(D)
        o = jnp.einsum("bkrt,btkd->bkrd", jax.nn.softmax(sc, axis=-1), v)
        logits = (o.reshape(B, H * D) @ wo) @ wu
        return k[:, None], v[:, None], jnp.argmax(logits, -1).astype(jnp.int32)

    @jax.jit
    def decode_core(kp, vp, tokens, positions, tables, lengths):
        x = emb[tokens]                               # (B, Dm)
        b = tokens.shape[0]
        q = (x @ wq).reshape(b, H, D)
        k = (x @ wk).reshape(b, K, D)
        v = (x @ wv).reshape(b, K, D)
        page = tables[jnp.arange(b), positions // PAGE]
        kp = kp.at[0, page, positions % PAGE].set(k)  # scatter the new token
        vp = vp.at[0, page, positions % PAGE].set(v)
        o = paged_attention_ref(q, kp[0], vp[0], tables, lengths + 1)
        logits = (o.reshape(b, H * D) @ wo) @ wu
        return kp, vp, jnp.argmax(logits, -1).astype(jnp.int32)
    decode_core = jax.jit(decode_core, donate_argnums=(0, 1))

    def prefill_fn(tokens):
        _occupy(devices[0].jax_device, tokens.shape[0] * tokens.shape[1] * _PRE_TOK_S)
        return prefill_core(tokens)

    def decode_fn(kp, vp, tokens, positions, tables, lengths):
        _occupy(_dev_of(kp), tokens.shape[0] * _TOK_S)
        return decode_core(kp, vp, tokens, positions, tables, lengths)

    return prefill_fn, decode_fn, decode_core, K, D

# Multi-model fleet: two GQA LMs of different sizes share the scheduler.
# Built ONCE so both fleet labels hit the same jit caches.
MODELS = ((0, 512, 128, 4, 2), (1, 256, 64, 4, 2))
LMS = [make_paged_lm(*m) for m in MODELS]
POOL_PAGES = 192
plens = [4, 8, 16]
work = sorted(
    [(i % 2, plens[int(v)], NEW) for i, v in enumerate(rng.integers(0, 3, size=S))],
    key=lambda t: (t[0], t[1]))  # sorted: deterministic prefill groups

def paged_pass(devs, label):
    sched = Scheduler(devs, policy="least_loaded")
    # Palette of decode row counts this fleet can see: steady state is
    # seqs-per-engine split over len(devs) lanes; 4x headroom covers skew.
    avg = max(1, -(-(S // 2) // len(devs)))
    shapes = tuple(b for b in (1, 2, 4, 8, 16, 32, 64)
                   if b <= min(S // 2, 4 * avg))
    engines = []
    for (seed, *_), (pf, df, core, kh, hd) in zip(MODELS, LMS):
        kv = PagedKVCache(PageSpec(1, PAGE, kh, hd), devices=devs,
                          pool_pages=POOL_PAGES)
        engines.append(PagedServeEngine(
            kv, pf, df, max_seq_len=MAXLEN, scheduler=sched,
            prefill=LanePolicy(max_batch=16, max_delay_s=0.05, token_budget=1024),
            decode=LanePolicy(max_batch=64, max_delay_s=0.05),
            decode_shapes=shapes,
            name=f"fig9-paged-{label}-m{seed}"))

    # Prewarm every palette shape on every device OUTSIDE the measured
    # window: jit caches key on (rows x device), so a first use inside a
    # measured rep would charge a ~100ms compile to some token's p99.
    M = MAXLEN // PAGE
    for pf, df, core, kh, hd in LMS:
        for d in devs:
            sh = (1, POOL_PAGES, PAGE, kh, hd)
            kz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            vz = jax.device_put(np.zeros(sh, np.float32), d.jax_device)
            for b in shapes:
                kz, vz, _ = core(kz, vz, np.zeros(b, np.int32),
                                 np.zeros(b, np.int32),
                                 np.zeros((b, M), np.int32),
                                 np.zeros(b, np.int32))
            jax.block_until_ready((kz, vz))

    def one_pass():
        t0 = time.perf_counter()
        futs = [engines[mi].submit(np.arange(plen, dtype=np.int32) % 100, nnew)
                for mi, plen, nnew in work]
        outs = [np.asarray(f.get()) for f in futs]
        return outs, time.perf_counter() - t0

    one_pass()  # warm: compiles the prefill groups and warm decode shapes
    best = None
    for _ in range(REPS):
        for e in engines:
            e.reset_metrics()
        outs, wall = one_pass()
        ms = [e.metrics() for e in engines]
        if best is None or wall < best[1]:
            best = (outs, wall, ms)
    for e in engines:
        e.close()
    outs, wall, ms = best
    rows = sum(m["rows"] for m in ms)
    padded = sum(m["padded_rows"] for m in ms)
    print(f"CSVROW,fig9/serving_paged_{label},{wall / S * 1e6:.1f},"
          f"seqs_per_s={S / wall:.2f};"
          f"p99_tok_ms={max(m['token_latency_p99_s'] for m in ms) * 1e3:.1f};"
          f"ttft_p99_ms={max(m['ttft_p99_s'] for m in ms) * 1e3:.1f};"
          f"waste={(padded / rows) if rows else 0.0:.3f};"
          f"slo_ms={SLO_MS:.0f};migrations={sum(m['migrations'] for m in ms)};"
          f"sequences={S};new_tokens={NEW}")
    return outs

out1 = paged_pass(devices[:1], "1dev")
out8 = paged_pass(devices, "8dev")
# Same prompts, same models, two fleets: greedy tokens must agree bit-for-bit.
assert all(np.array_equal(a, b) for a, b in zip(out1, out8)), "paged fleets diverged"
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if len(rows) < 5 or proc.returncode != 0:
        rows.append(
            {"name": "fig9/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
