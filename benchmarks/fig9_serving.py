"""Fig. 9 (extension): continuous-batching serving throughput (DESIGN.md §12).

The ``RequestEngine`` exists to keep accelerators utilized under many
small concurrent requests: per-request dispatch overhead (queue hop,
device_put, executable lookup) is paid once per MICRO-BATCH instead of
once per request.  This benchmark drives identical request streams two
ways:

* ``serial``  — per-request serving: each request runs alone through
  ``Program.run`` and is waited on before the next starts (the no-engine
  baseline every request-level server starts from).
* ``batched`` — all requests submitted concurrently to a
  ``RequestEngine`` (max_batch=8): the engine assembles micro-batches,
  pads to buckets, replays the captured step on an engine stream and
  slices per-request results.

Rows report seconds per request (us_per_call column), with requests/s and
latency p50/p99 in the derived field; a forced-8-device row shows the
same stream spread over a fleet by ``least_loaded``.  The workload is
deliberately small per request — overhead-bound, the serving regime the
engine targets — and identical (bit-equal results asserted) across modes.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=8``
and parses the CSV it prints (the fig6 pattern).  Results land in
``BENCH_serving.json`` via ``benchmarks/run.py``; CI asserts the batched
row beats the serial row.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_cpu_multi_thread_eigen=false "
                           + os.environ.get("XLA_FLAGS", ""))
import time

import numpy as np
import jax
from repro.core import Scheduler, get_all_devices, wait_all
from repro.kernels.partition_map.ref import partition_map_ref
from repro.serving import RequestEngine

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
N = 256
LOOPS = 2 if quick else 4
R = 32 if quick else 64
REPS = 2 if quick else 3

def step(x):
    def body(i, v):
        return partition_map_ref(v) * 0.5 + v * 0.5
    return jax.lax.fori_loop(0, LOOPS, body, x)

devices = get_all_devices(1, 0).get()
assert len(devices) == 8, devices
dev = devices[0]
rng = np.random.default_rng(0)
payloads = [rng.normal(size=(1, N)).astype(np.float32) for _ in range(R)]

def pct(lats, q):
    ls = sorted(lats)
    return ls[int(q * (len(ls) - 1))]

# --- serial: one request at a time through Program.run ----------------------
prog = dev.create_program({"step": step}, "fig9").get()
prog.run([payloads[0]], "step").get()  # warm the executable

def serial_pass():
    lats = []
    t0 = time.perf_counter()
    for p in payloads:
        t = time.perf_counter()
        prog.run([p], "step").get()
        lats.append(time.perf_counter() - t)
    return time.perf_counter() - t0, lats

serial_pass()
best_wall, best_lats = min((serial_pass() for _ in range(REPS)), key=lambda r: r[0])
ref = [np.asarray(prog.run([p], "step").get()) for p in payloads]
print(f"CSVROW,fig9/serving_serial_1dev,{best_wall / R * 1e6:.1f},"
      f"rps={R / best_wall:.1f};p50_ms={pct(best_lats, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(best_lats, 0.99) * 1e3:.2f};requests={R}")

# --- batched: concurrent submission through the RequestEngine ----------------
def engine_pass(sched, name):
    eng = RequestEngine(step, max_batch=8, max_delay_s=0.002, max_queue=4 * R,
                        scheduler=sched, name=name)
    try:
        wait_all([eng.submit(p) for p in payloads])  # warm every bucket route
        best = None
        for _ in range(REPS):
            t0 = time.perf_counter()
            stamped = []
            for p in payloads:
                ts = time.perf_counter()
                f = eng.submit(p)
                # client-observed latency: submit -> slice resolution
                stamped.append(f.then(
                    lambda v, ts=ts: (time.perf_counter() - ts, v), executor="inline"
                ))
            wait_all(stamped)
            wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, stamped)
        wall, stamped = best
        lats = []
        for want, f in zip(ref, stamped):
            lat, got = f.get()
            lats.append(lat)
            assert got.dtype == want.dtype and np.array_equal(got, want), "diverged"
        return wall, lats, eng.metrics()
    finally:
        eng.close()

wall, lats, m = engine_pass(Scheduler([dev], policy="least_loaded"), "fig9-1dev")
print(f"CSVROW,fig9/serving_batched_1dev,{wall / R * 1e6:.1f},"
      f"rps={R / wall:.1f};p50_ms={pct(lats, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(lats, 0.99) * 1e3:.2f};"
      f"mean_batch={m['mean_batch_rows']:.1f};requests={R}")

sched8 = Scheduler(devices, policy="least_loaded")
wall8, lats8, m8 = engine_pass(sched8, "fig9-8dev")
print(f"CSVROW,fig9/serving_batched_8dev,{wall8 / R * 1e6:.1f},"
      f"rps={R / wall8:.1f};p50_ms={pct(lats8, 0.5) * 1e3:.2f};"
      f"p99_ms={pct(lats8, 0.99) * 1e3:.2f};"
      f"mean_batch={m8['mean_batch_rows']:.1f};spread={len(sched8.stats())};requests={R}"
)
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if len(rows) < 3 or proc.returncode != 0:
        rows.append(
            {"name": "fig9/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
