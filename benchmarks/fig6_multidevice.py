"""Paper Fig. 6: multi-device partition benchmark (1..4 devices).

Each partition of the vector is handled by one device through the SAME
location-transparent API (``get_all_devices`` + per-device queues) — the
paper's 2x dual-GPU K80 topology mapped to 4 host devices.

The second section drives the same partition workload through the
placement scheduler (``Program.run_on_any``, DESIGN.md §9), one row per
policy, so the 1→4-device scaling curve compares hand placement against
``static`` / ``round_robin`` / ``least_loaded`` / ``affinity``.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=4``
and parses the CSV it prints.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax
from benchmarks.common import timeit
from repro.core import get_all_devices, wait_all
from repro.kernels.partition_map.ops import partition_map

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
ms = (1, 4) if quick else (1, 3, 5)
devices = get_all_devices(1, 0).get()
assert len(devices) == 4, devices
progs = {d.key: d.create_program({"k": lambda x: partition_map(x, impl="ref")}, f"fig6-{d.key}").get() for d in devices}

for m in ms:
    n = (2**m) * 1024 * 256 // (4 if quick else 1)
    for ndev in (1, 2, 3, 4):
        parts = np.array_split(np.random.default_rng(0).normal(size=(n,)).astype(np.float32), ndev)
        devs = devices[:ndev]

        def pipeline():
            reads = []
            for d, h in zip(devs, parts):
                b = d.create_buffer_from(np.ascontiguousarray(h))
                o = b.then(lambda buf, d=d: progs[d.key].run([buf], "k", out=[buf]).get())
                reads.append(o.then(lambda bl: bl[0].enqueue_read().get()))
            wait_all(reads)
            return [r.get() for r in reads]

        pipeline()
        t = timeit(pipeline, iters=4 if quick else 11)
        print(f"CSVROW,fig6/partition_n{n}_dev{ndev},{t*1e6:.1f},devices={ndev}")

# --- scheduler policies over the same workload (run_on_any) -----------------
# Inputs are DEVICE-RESIDENT buffers spread round-robin: affinity reads the
# AGAS placement records and keeps each chunk where its bytes live (zero
# percolation); the other policies pay the copy whenever they place a chunk
# away from its home device.
from repro.core import Scheduler
n = (2**ms[-1]) * 1024 * 256 // (4 if quick else 1)
chunks = 8 if quick else 16
parts = [np.ascontiguousarray(p) for p in
         np.array_split(np.random.default_rng(0).normal(size=(n,)).astype(np.float32), chunks)]
bufs = [devices[i % len(devices)].create_buffer_from(p).get() for i, p in enumerate(parts)]
prog0 = progs[devices[0].key]

for policy in ("static", "round_robin", "least_loaded", "affinity"):
    sched = Scheduler(devices, policy=policy)

    def pipeline():
        futs = [prog0.run_on_any([b], "k", scheduler=sched) for b in bufs]
        wait_all(futs)
        return [f.get() for f in futs]

    pipeline()  # warm-up: compiles the per-device siblings the policy reaches
    t = timeit(pipeline, iters=4 if quick else 11)
    spread = len(sched.stats())  # distinct devices the policy placed on
    print(f"CSVROW,fig6/policy_{policy}_n{n},{t*1e6:.1f},devices=4;policy={policy};spread={spread}")
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if not rows or proc.returncode != 0:
        # A nonzero exit must surface even when earlier sections already
        # printed rows (a crash mid-script would otherwise pass silently).
        rows.append(
            {"name": "fig6/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
