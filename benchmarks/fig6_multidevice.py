"""Paper Fig. 6: multi-device partition benchmark (1..4 devices).

The vector is split into chunks and every chunk is launched through
``Program.run_on_any`` over a ``dev_k`` fleet — the paper's 2x dual-GPU
K80 topology mapped to 4 host devices, driven by the rebalancing
scheduler (steal pool + memory-aware placement, DESIGN.md §14) instead
of hand placement.

**Occupancy model.**  A CPU-only runner has one set of cores behind all
"devices", so N forced host devices can never genuinely beat 1 on raw
compute — the seed benchmark showed *negative* scaling because each
extra device only added dispatch overhead.  As with the fig8 wire clock,
the device time is therefore modeled: the kernel is an eager-fallback
callable that *occupies its device lane* for ``size / _ELEMS_PER_S``
(a ``time.sleep`` — it releases the GIL, so k lanes overlap exactly like
k real devices) and then computes the real partition math in numpy.
Everything the runtime is responsible for — placement, the per-device
pending deques, pump/steal scheduling, lane FIFO — is exercised for
real; only the per-element device clock is synthetic.

The second section drives the same chunks (device-resident buffers,
spread round-robin) through one row per placement policy with stealing
OFF, so the scaling curve compares the *placement signal* alone:
``static`` / ``round_robin`` / ``least_loaded`` / ``affinity``.  CI
gates ``dev4 < dev1`` and ``least_loaded <= 1.05 * round_robin`` on the
emitted ``BENCH_multidevice.json``.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=4``
and parses the CSV it prints.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
import time
import numpy as np
from benchmarks.common import timeit
from repro.core import Scheduler, get_all_devices, wait_all

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
ms = (1, 4) if quick else (1, 3, 5)
iters = 4 if quick else 11
CHUNKS = 16
ELEMS_PER_S = 10e6  # modeled device clock (see module docstring)

devices = get_all_devices(1, 0).get()
assert len(devices) == 4, devices

def kern(x):
    h = np.asarray(x)               # tracer -> eager fallback at build time
    time.sleep(h.size / ELEMS_PER_S)  # modeled occupancy; releases the GIL
    return np.sin(h) * 0.5 + h * 0.5

progs = {d.key: d.create_program({"k": kern}, f"fig6-{d.key}").get() for d in devices}
prog0 = progs[devices[0].key]

def model(h):
    return np.sin(h) * 0.5 + h * 0.5

# --- dev_k scaling through the rebalancing scheduler ------------------------
for m in ms:
    n = (2**m) * 1024 * 256 // (4 if quick else 1)
    parts = [np.ascontiguousarray(p) for p in
             np.array_split(np.random.default_rng(0).normal(size=(n,)).astype(np.float32), CHUNKS)]
    for ndev in (1, 2, 3, 4):
        sched = Scheduler(devices[:ndev], policy="least_loaded")

        def pipeline():
            futs = [prog0.run_on_any([p], "k", scheduler=sched) for p in parts]
            wait_all(futs)
            return [f.get() for f in futs]

        res = pipeline()  # warm-up: builds every sibling the fleet reaches
        np.testing.assert_allclose(np.asarray(res[0]), model(parts[0]), rtol=1e-6)
        t = timeit(pipeline, iters=iters)
        steals = sched.steal_stats()["steals"]
        print(f"CSVROW,fig6/partition_n{n}_dev{ndev},{t*1e6:.1f},devices={ndev};steals={steals}")

# --- scheduler policies over the same workload (stealing OFF) ---------------
# Inputs are DEVICE-RESIDENT buffers spread round-robin: affinity reads the
# AGAS placement records and keeps each chunk where its bytes live (zero
# percolation); the other policies pay the copy whenever they place a chunk
# away from its home device.  Stealing is disabled so each row measures the
# PLACEMENT signal alone — the steal pool would let idle lanes hide even a
# static pile-up.
n = (2**ms[-1]) * 1024 * 256 // (4 if quick else 1)
chunks = 8 if quick else CHUNKS
parts = [np.ascontiguousarray(p) for p in
         np.array_split(np.random.default_rng(0).normal(size=(n,)).astype(np.float32), chunks)]
bufs = [devices[i % len(devices)].create_buffer_from(p).get() for i, p in enumerate(parts)]

for policy in ("static", "round_robin", "least_loaded", "affinity"):
    sched = Scheduler(devices, policy=policy, steal=False)

    def pipeline():
        futs = [prog0.run_on_any([b], "k", scheduler=sched) for b in bufs]
        wait_all(futs)
        return [f.get() for f in futs]

    pipeline()  # warm-up: compiles the per-device siblings the policy reaches
    t = timeit(pipeline, iters=iters)
    spread = len(sched.stats())  # distinct devices the policy placed on
    print(f"CSVROW,fig6/policy_{policy}_n{n},{t*1e6:.1f},devices=4;policy={policy};spread={spread}")
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    env.pop("REPRO_STEAL", None)  # the child toggles stealing per section
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if not rows or proc.returncode != 0:
        # A nonzero exit must surface even when earlier sections already
        # printed rows (a crash mid-script would otherwise pass silently).
        rows.append(
            {"name": "fig6/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
