"""Paper Fig. 4: partition benchmark — both drivers asynchronous.

The vector is sliced into p=4 partitions; each partition is copied to the
device, mapped through k(x)=sqrt(sin^2+cos^2), and copied back, with the
per-partition pipelines overlapping.  Native uses raw JAX async dispatch;
futurized drives the same pipeline through the runtime's future graph.
Paper claim: difference ~4% (the layer is negligible once the baseline
also overlaps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import dataflow, get_all_devices, wait_all
from repro.kernels.partition_map.ops import partition_map

P_PARTS = 4
BLOCK = 256


def run(quick: bool = False):
    ms = (1, 4) if quick else (1, 2, 3, 4)  # full-size m>4 is minutes on 1 CPU core
    rows = []
    dev = get_all_devices(1, 0).get()[0]
    prog = dev.create_program({"k": lambda x: partition_map(x, impl="ref")}, "fig4").get()
    jitted = jax.jit(lambda x: partition_map(x, impl="ref"))

    for m in ms:
        n = (2**m) * 1024 * BLOCK * P_PARTS // (8 if quick else 1)
        part = n // P_PARTS
        hosts = [
            np.random.default_rng(i).normal(size=(part,)).astype(np.float32)
            for i in range(P_PARTS)
        ]

        def native_async():
            # overlap via async dispatch: issue all copies+kernels, then sync
            ys = [jitted(jax.device_put(h)) for h in hosts]
            return [np.asarray(y) for y in ys]

        def futurized():
            reads = []
            for h in hosts:
                b = dev.create_buffer_from(h)
                # sync="dispatch": the later enqueue_read on the same device
                # queue is ordered after the launch (CUDA-stream semantics)
                o = b.then(lambda buf: prog.run([buf], "k", out=[buf], sync="dispatch").get())
                reads.append(o.then(lambda bl: bl[0].enqueue_read().get()))
            wait_all(reads)
            return [r.get() for r in reads]

        native_async()
        futurized()
        t_nat = timeit(native_async, iters=6 if quick else 11)
        t_fut = timeit(futurized, iters=6 if quick else 11)
        delta = (t_fut - t_nat) / t_nat * 100
        rows.append({"name": f"fig4/native_async_n{n}", "s": t_nat, "derived": ""})
        rows.append(
            {"name": f"fig4/futurized_n{n}", "s": t_fut, "derived": f"overhead={delta:+.1f}%"}
        )
    return rows
