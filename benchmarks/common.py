"""Shared benchmark machinery, following the paper's §5 protocol:
11 iterations, the first is warm-up and ignored, report the mean of the
last 10."""
from __future__ import annotations

import time
from typing import Callable

ITERS = 11


def timeit(fn: Callable[[], None], iters: int = ITERS) -> float:
    """Mean seconds over the last ``iters - 1`` runs (first = warm-up)."""
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    rest = ts[1:]
    return sum(rest) / len(rest)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
