"""Fig. 8 (extension): transfer–compute overlap via streams (DESIGN.md §11).

The paper's central performance claim is that asynchronous transfers and
kernel launches overlap; this benchmark is the overlap figure for our
stream engine.  A chunked double-buffered pipeline — H2D wire + copy ->
kernel -> D2H copy + wire -> host consume, per chunk — is driven two
ways over identical inputs:

* ``1stream`` — every operation on ONE stream: same-stream FIFO
  serializes the stages exactly like the pre-stream single-lane runtime.
* ``2stream`` — chunks alternate between two streams (double buffering,
  2 buffer slots); kernels are serialized onto one "compute engine" with
  completion ``record``/``wait_event`` event edges (the CUDA copy-engine
  pattern), so chunk ``i+1``'s transfers ride their own lane and overlap
  chunk ``i``'s kernel.

**Transfer model.**  On a CPU-only runner there is no DMA engine: a host
"transfer" is a memcpy competing with the kernel for the same cores, so
transfer–compute overlap is structurally zero-sum whatever the runtime
does.  The wire time is therefore modeled: each transfer occupies its
stream for ``nbytes / BW`` (plus the real copy), with ``BW`` scaled so
the transfer:compute ratio matches a PCIe-attached accelerator driving
kernels ~2x the wire time — the regime of the paper's overlap figure.
Everything the engine is responsible for — lane FIFO, event
happens-before, concurrent lanes — is exercised for real; only the wire
clock is synthetic.  The dispatcher's lane high-water mark (>1) is
asserted, so a regression that silently serializes the lanes fails this
benchmark even if wall-clock noise would mask it.

Rows report the median over interleaved 1-stream/2-stream runs (both
configurations face the same noise), the measured speedup, and the lane
high-water mark.  Results land in ``BENCH_overlap.json`` via
``benchmarks/run.py`` and CI.
"""
from __future__ import annotations

import statistics
import time

import numpy as np

# Modeled interconnect bandwidth.  Our interpreted-CPU "device" runs
# kernels ~2 orders of magnitude slower than a real accelerator, so the
# wire is scaled down with it to keep the paper's transfer:compute ratio
# (a chunk's kernel ≈ 2x its one-way wire time).
_WIRE_BYTES_PER_S = 400e6


def _wire(nbytes: int) -> float:
    return nbytes / _WIRE_BYTES_PER_S


def run(quick: bool = False):
    import jax.numpy as jnp

    from repro.core import get_all_devices

    def work(x):
        for _ in range(2):
            x = jnp.sin(x) * 1.0001 + x * 0.5
        return x

    n = 1 << 21  # full-size chunks even in quick mode: stage times must
    nchunks = 6 if quick else 8  # dwarf the ~0.1 ms per-op overhead
    iters = 3 if quick else 9

    dev = get_all_devices().get()[0]
    prog = dev.create_program({"work": work}, "fig8").get()
    rng = np.random.default_rng(0)
    chunks = [rng.normal(size=(n,)).astype(np.float32) for _ in range(nchunks)]
    nbytes = chunks[0].nbytes
    inb = [dev.create_buffer(n, np.float32).get() for _ in range(2)]
    outb = [dev.create_buffer(n, np.float32).get() for _ in range(2)]

    def pipeline(streams):
        """Chunked H2D -> kernel -> D2H -> consume over 2 buffer slots."""
        k = len(streams)
        sums, prev_kernel = [], None
        for i, c in enumerate(chunks):
            s = streams[i % k]
            s.submit(time.sleep, _wire(nbytes))  # H2D wire occupancy
            s.enqueue_write(inb[i % 2], 0, c)
            if prev_kernel is not None and k > 1:
                s.wait_event(prev_kernel)  # one compute engine across streams
            s.launch(prog, [inb[i % 2]], "work", out=[outb[i % 2]])
            if k > 1:
                prev_kernel = s.record()  # completion event (kernel done)
            r = s.enqueue_read(outb[i % 2])
            s.submit(time.sleep, _wire(nbytes))  # D2H wire occupancy
            # Host-side consume, stream-ordered (cudaLaunchHostFunc): r is
            # resolved by same-stream FIFO before this callback runs.
            sums.append(s.submit(lambda f=r: float(f.get()[0])))
        return [f.get() for f in sums]

    one = [dev.create_stream("fig8-serial")]
    two = [dev.create_stream("fig8-a"), dev.create_stream("fig8-b")]

    ref = pipeline(one)  # warm-up both configurations; check equivalence
    if pipeline(two) != ref:
        return [{"name": "fig8/FAILED", "s": -1.0,
                 "derived": "2-stream pipeline diverged from 1-stream"}]

    dev._dispatcher.reset_high_water()
    t1s, t2s = [], []
    for _ in range(iters):  # interleaved: both configs see the same noise
        t0 = time.perf_counter()
        pipeline(one)
        t1s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        pipeline(two)
        t2s.append(time.perf_counter() - t0)
    hwm = dev._dispatcher.high_water()

    m1, m2 = statistics.median(t1s), statistics.median(t2s)
    rows = [
        {
            "name": f"fig8/pipeline_1stream_n{n}x{nchunks}",
            "s": m1,
            "derived": f"streams=1;chunk_mb={nbytes / 1e6:.1f};wire_ms={_wire(nbytes) * 1e3:.1f}",
        },
        {
            "name": f"fig8/pipeline_2stream_n{n}x{nchunks}",
            "s": m2,
            "derived": f"streams=2;speedup={m1 / m2:.2f};lane_high_water={hwm}",
        },
    ]
    if hwm < 2:
        rows.append({"name": "fig8/FAILED", "s": -1.0,
                     "derived": f"no lane concurrency observed (high_water={hwm})"})
    return rows
