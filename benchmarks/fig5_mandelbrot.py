"""Paper Fig. 5: Mandelbrot — synchronous vs asynchronous result writing.

Computes escape-iteration images of increasing size; the sync driver
blocks on writing each image to disk before computing the next; the async
driver hands the write to ``async_`` (a host-pool future) and immediately
starts the next image — the pattern our checkpoint module generalizes.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import timeit
from repro.core import async_, wait_all
from repro.kernels.mandelbrot.ref import mandelbrot_ref


def run(quick: bool = False):
    sizes = (128, 256) if quick else (128, 256, 512, 1024)
    rows = []
    tmp = tempfile.mkdtemp(prefix="mandel_")

    for hw in sizes:
        import jax

        jitted = jax.jit(lambda: mandelbrot_ref(hw, hw, 64))
        jitted().block_until_ready()

        def write(img, tag):
            np.save(os.path.join(tmp, f"img_{hw}_{tag}.npy"), np.asarray(img))

        def sync(n_imgs: int = 4):
            for i in range(n_imgs):
                img = jitted()
                img.block_until_ready()
                write(img, f"s{i}")

        def async_write(n_imgs: int = 4):
            futs = []
            for i in range(n_imgs):
                img = jitted()  # async dispatch
                futs.append(async_(write, img, f"a{i}"))  # I/O on host pool
            wait_all(futs)

        sync()
        async_write()
        t_sync = timeit(sync, iters=4 if quick else 11)
        t_async = timeit(async_write, iters=4 if quick else 11)
        gain = (t_sync - t_async) / t_sync * 100
        rows.append({"name": f"fig5/mandel_syncwrite_{hw}", "s": t_sync, "derived": ""})
        rows.append(
            {"name": f"fig5/mandel_asyncwrite_{hw}", "s": t_async,
             "derived": f"vs_sync={gain:+.1f}%"}
        )
    return rows
