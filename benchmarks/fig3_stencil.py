"""Paper Fig. 3: PRK 3-point stencil, synchronous-native vs futurized.

The paper's native baseline executed CUDA calls *sequentially*
(synchronous memcpy, kernel, memcpy); HPXCL overlapped H2D / compile /
launch via futures and came out ~28% faster.  We reproduce both drivers:
  sync      — device_put / block / kernel / block / host read per step
  futurized — enqueue_write + build + run + read futures composed,
              the host prepares the NEXT input while the device works.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import get_all_devices, wait_all
from repro.kernels.stencil.ops import stencil
from repro.kernels.stencil.ref import stencil_ref


def run(quick: bool = False):
    # paper sizes (m=1..8 -> n<=262k) target K40-era PCIe latencies; on a
    # CPU host the per-item work must dwarf the ~ms python-thread hops for
    # the overlap effect to be visible at all, so we shift the range up
    ms = (4, 8) if quick else (8, 9, 10, 11, 12)
    rows = []
    dev = get_all_devices(1, 0).get()[0]
    prog = dev.create_program({"stencil": lambda x: stencil(x, impl="ref")}, "fig3").get()
    jitted = jax.jit(lambda x: stencil(x, impl="ref"))

    for m in ms:
        n = (2**m) * 1024
        hosts = [np.random.default_rng(i).normal(size=(n,)).astype(np.float32) for i in range(4)]

        def sync():
            outs = []
            for h in hosts:  # fully synchronous: each stage blocks
                x = jax.device_put(h)
                x.block_until_ready()
                y = jitted(x)
                y.block_until_ready()
                outs.append(np.asarray(y))
            return outs

        def futurized():
            bufs = [dev.create_buffer_from(h) for h in hosts]  # async H2D
            outs = [
                b.then(lambda buf: prog.run([buf], "stencil", out=[buf], sync="dispatch").get())
                for b in bufs
            ]
            reads = [o.then(lambda bl: bl[0].enqueue_read().get()) for o in outs]
            wait_all(reads)
            return [r.get() for r in reads]

        sync()  # warm
        futurized()
        t_sync = timeit(sync, iters=6 if quick else 11)
        t_fut = timeit(futurized, iters=6 if quick else 11)
        speedup = (t_sync - t_fut) / t_sync * 100
        rows.append(
            {"name": f"fig3/stencil_sync_n{n}", "s": t_sync, "derived": ""}
        )
        rows.append(
            {"name": f"fig3/stencil_futurized_n{n}", "s": t_fut,
             "derived": f"vs_sync={speedup:+.1f}%"}
        )
    return rows
