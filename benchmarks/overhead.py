"""Runtime-layer overhead microbenchmark (paper §5 headline claim).

Same kernel, same data, two drivers:
  native   — raw JAX dispatch (the "native CUDA" analogue),
  futurized— through Device/Buffer/Program + futures (the HPXCL analogue).

The paper's claim under test: the additional layer imposes no additional
computational overhead (Fig. 4: ~4% with async native baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import Dim3, get_all_devices, wait_all
from repro.kernels.partition_map.ops import partition_map


def run(quick: bool = False):
    n = 2**18 if quick else 2**20
    host = np.random.default_rng(0).normal(size=(n,)).astype(np.float32)

    # --- native: jit dispatch + block
    jitted = jax.jit(lambda x: partition_map(x, impl="ref"))
    xdev = jnp.asarray(host)
    jitted(xdev).block_until_ready()  # compile outside timing

    def native():
        jitted(xdev).block_until_ready()

    t_native = timeit(native)

    # --- futurized: full HPXCL-style path (buffers + program + futures)
    dev = get_all_devices(1, 0).get()[0]
    buf = dev.create_buffer_from(host).get()
    out = dev.create_buffer(n, np.float32).get()
    prog = dev.create_program({"k": lambda x: partition_map(x, impl="ref")}, "bench").get()
    prog.run([buf], "k", out=[out]).get()  # warm compile cache

    def futurized():
        prog.run([buf], "k", grid=Dim3(1), block=Dim3(256), out=[out]).get()

    t_fut = timeit(futurized)

    # --- layer-only cost: submit a no-op through the whole future chain
    noop = dev.create_program({"id": lambda x: x}, "noop").get()
    noop.run([buf], "id").get()

    def layer_only():
        noop.run([buf], "id").get()

    t_layer = timeit(layer_only)

    ovh = (t_fut - t_native) / t_native * 100
    return [
        {"name": "overhead/native_dispatch", "s": t_native, "derived": f"n={n}"},
        {"name": "overhead/futurized", "s": t_fut, "derived": f"overhead={ovh:+.1f}%"},
        {"name": "overhead/layer_noop", "s": t_layer, "derived": "future+queue+launch path"},
    ]
