"""Runtime-layer overhead microbenchmark (paper §5 headline claim).

Same kernel, same data, three drivers:
  native       — raw JAX dispatch (the "native CUDA" analogue),
  futurized    — through Device/Buffer/Program + futures (HPXCL analogue),
  graph_replay — the chain captured once into a TaskGraph and replayed as
                 one fused executable + one queue hop (CUDA Graphs
                 analogue, DESIGN.md §8).

Plus per-primitive rows so the layer cost decomposes in the perf
trajectory: future creation, a bare ops-queue hop, and the compiled
launch alone.

The paper's claim under test: the additional layer imposes no additional
computational overhead (Fig. 4: ~4% with async native baseline); the graph
path must beat the eager futurized path by amortizing scheduling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import Dim3, TaskGraph, get_all_devices, make_ready_future, wait_all
from repro.kernels.partition_map.ops import partition_map


def run(quick: bool = False):
    n = 2**18 if quick else 2**20
    host = np.random.default_rng(0).normal(size=(n,)).astype(np.float32)

    # --- native: jit dispatch + block
    jitted = jax.jit(lambda x: partition_map(x, impl="ref"))
    xdev = jnp.asarray(host)
    jitted(xdev).block_until_ready()  # compile outside timing

    def native():
        jitted(xdev).block_until_ready()

    t_native = timeit(native)

    # --- futurized: full HPXCL-style path (buffers + program + futures)
    dev = get_all_devices(1, 0).get()[0]
    buf = dev.create_buffer_from(host).get()
    out = dev.create_buffer(n, np.float32).get()
    prog = dev.create_program({"k": lambda x: partition_map(x, impl="ref")}, "bench").get()
    # Warm the compile cache with the *same* grid/block as the timed call —
    # the executable cache is keyed on launch geometry, so a bare warm-up
    # would leave the first timed iteration paying a fresh XLA compile.
    prog.run([buf], "k", grid=Dim3(1), block=Dim3(256), out=[out]).get()

    def futurized():
        prog.run([buf], "k", grid=Dim3(1), block=Dim3(256), out=[out]).get()

    t_fut = timeit(futurized)

    # --- chain of 3 launches: the task-DAG case graphs are built for.
    # Eager pays 3 queue hops + 3 futures + 3 separate executables; the
    # pre-bound graph replays as one lane enqueue + one future.  The DAG
    # rows run at their own size: at the headline n the transcendental
    # kernel swamps the dispatch tax these rows exist to measure (the
    # per-launch runtime cost is size-independent, the compute is not),
    # so 2^14 keeps compute real but the dispatch difference visible.
    n_dag = 2**14
    dhost = host[:n_dag]
    dbuf = dev.create_buffer_from(dhost).get()
    tmp1 = dev.create_buffer(n_dag, np.float32).get()
    tmp2 = dev.create_buffer(n_dag, np.float32).get()
    cout = dev.create_buffer(n_dag, np.float32).get()

    def futurized_chain3():
        prog.run([dbuf], "k", grid=Dim3(1), block=Dim3(256), out=[tmp1]).get()
        prog.run([tmp1], "k", grid=Dim3(1), block=Dim3(256), out=[tmp2]).get()
        prog.run([tmp2], "k", grid=Dim3(1), block=Dim3(256), out=[cout]).get()

    futurized_chain3()  # warm (same geometry -> same executable cache entry)
    t_chain = timeit(futurized_chain3)

    gt1 = dev.create_buffer(n_dag, np.float32).get()
    gt2 = dev.create_buffer(n_dag, np.float32).get()
    gout = dev.create_buffer(n_dag, np.float32).get()
    g = TaskGraph("bench-replay")
    g.run(prog, [dbuf], "k", grid=Dim3(1), block=Dim3(256), out=[gt1])
    g.run(prog, [gt1], "k", grid=Dim3(1), block=Dim3(256), out=[gt2])
    g.run(prog, [gt2], "k", grid=Dim3(1), block=Dim3(256), out=[gout])
    exe = g.instantiate()
    exe.replay().get()  # warm

    def graph_replay():
        exe.replay().get()

    t_graph = timeit(graph_replay)

    # --- same chain, ONE coalesced submission scope: the three eager
    # launches stage thread-locally and enter the queue as a single put
    # (same-queue FIFO keeps the dependency order); only the last future
    # is consumed.  Isolates the per-hop scheduling tax the graph path
    # also amortizes, without capture/instantiate.
    from repro.core import coalesce

    def coalesced_chain3():
        with coalesce():
            prog.run([dbuf], "k", grid=Dim3(1), block=Dim3(256), out=[tmp1])
            prog.run([tmp1], "k", grid=Dim3(1), block=Dim3(256), out=[tmp2])
            f = prog.run([tmp2], "k", grid=Dim3(1), block=Dim3(256), out=[cout])
        f.get()

    coalesced_chain3()
    t_cchain = timeit(coalesced_chain3)

    # --- pre-bound replay dispatch: a tiny single-node graph makes the
    # compute negligible, leaving the replay machinery itself — flat
    # pre-bound plan, one lane enqueue, one future (DESIGN.md §13).
    sbuf = dev.create_buffer_from(host[:256]).get()
    sout = dev.create_buffer(256, np.float32).get()
    sg = TaskGraph("bench-dispatch")
    sg.run(prog, [sbuf], "k", grid=Dim3(1), block=Dim3(256), out=[sout])
    sexe = sg.instantiate()
    sexe.replay().get()

    def replay_dispatch():
        sexe.replay().get()

    t_rdisp = timeit(replay_dispatch)

    # --- layer-only cost: submit a no-op through the whole future chain
    noop = dev.create_program({"id": lambda x: x}, "noop").get()
    noop.run([buf], "id").get()

    def layer_only():
        noop.run([buf], "id").get()

    t_layer = timeit(layer_only)

    # --- per-primitive decomposition of the layer cost
    def prim_future_ready():
        # create+consume 100 ready futures (no-alloc fast path)
        for _ in range(100):
            make_ready_future(0).get()

    t_fready = timeit(prim_future_ready) / 100

    _nop = lambda: None  # noqa: E731

    def prim_queue_hop():
        dev.ops_queue.submit(_nop).get()

    t_hop = timeit(prim_queue_hop)

    def prim_queue_hop_batched():
        # 16 submissions, one queue put (submit_many)
        wait_all(dev.ops_queue.submit_many([_nop] * 16))

    t_hop16 = timeit(prim_queue_hop_batched) / 16

    compiled = prog._cache[prog._key("k", [xdev], Dim3(1), Dim3(256))]

    def prim_launch_only():
        compiled(xdev).block_until_ready()

    t_launch = timeit(prim_launch_only)

    ovh = (t_fut - t_native) / t_native * 100
    return [
        {"name": "overhead/native_dispatch", "s": t_native, "derived": f"n={n}"},
        {"name": "overhead/futurized", "s": t_fut, "derived": f"overhead={ovh:+.1f}%"},
        {"name": "overhead/futurized_chain3", "s": t_chain, "derived": f"3 eager launches; n={n_dag}"},
        {"name": "overhead/graph_replay", "s": t_graph,
         "derived": f"same chain fused; vs_futurized_chain={(t_graph - t_chain) / t_chain * 100:+.1f}%"},
        {"name": "overhead/coalesced_chain3", "s": t_cchain,
         "derived": f"one staged hop; vs_eager_chain={(t_cchain - t_chain) / t_chain * 100:+.1f}%"},
        {"name": "overhead/replay_dispatch", "s": t_rdisp,
         "derived": "pre-bound single-hop replay; n=256"},
        {"name": "overhead/layer_noop", "s": t_layer, "derived": "future+queue+launch path"},
        {"name": "overhead/prim_future_ready", "s": t_fready, "derived": "no-alloc ready future"},
        {"name": "overhead/prim_queue_hop", "s": t_hop, "derived": "1 submit -> 1 put"},
        {"name": "overhead/prim_queue_hop_batched", "s": t_hop16, "derived": "per-call; 16 via submit_many"},
        {"name": "overhead/prim_launch_only", "s": t_launch, "derived": "cached executable call"},
    ]
