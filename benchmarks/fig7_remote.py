"""Fig. 7 (extension): remote-launch overhead — local vs loopback vs cluster.

The paper's §5 protocol (``benchmarks/common.timeit``: 11 iterations,
first discarded) applied to the same registered kernel launched three
ways:

* ``local``    — ``Program.run`` on this process's device (baseline),
* ``loopback`` — through a ``LoopbackParcelport`` locality: the full
  parcel path (encode, action dispatch, reply decode) without process
  hops — the codec + dispatch cost in isolation,
* ``cluster``  — through a ``LocalClusterParcelport`` worker process:
  adds the real IPC hop and cross-process scheduling.

Derived columns report the multiple over the local baseline, so the
transport tax is tracked per-PR in ``BENCH_remote.json`` alongside the
futurization (BENCH_overhead) and scaling (BENCH_multidevice) numbers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit

_KERNEL = "partition_map_ref"


def _time_launch(prog, x, iters: int) -> float:
    def launch():
        prog.run([x], _KERNEL).get()

    launch()  # warm-up: compile / create remote executables outside the clock
    return timeit(launch, iters=iters)


def run(quick: bool = False):
    from repro.core import LocalClusterParcelport, LoopbackParcelport, Program, get_all_devices
    from repro.core.parcel import resolve_kernel

    iters = 4 if quick else 11
    n = 1 << (12 if quick else 14)
    x = np.random.default_rng(0).normal(size=(n,)).astype(np.float32)
    rows = []

    dev = get_all_devices().get()[0]
    prog = Program(dev, {_KERNEL: resolve_kernel(_KERNEL)}, "fig7")
    t_local = _time_launch(prog, x, iters)
    rows.append({"name": f"fig7/local_launch_n{n}", "s": t_local, "derived": "transport=local"})

    loop = LoopbackParcelport(n_localities=1)
    try:
        rprog = loop.localities()[0].devices[0].create_program([_KERNEL], name="fig7-loop").get()
        t_loop = _time_launch(rprog, x, iters)
        rows.append({
            "name": f"fig7/loopback_launch_n{n}", "s": t_loop,
            "derived": f"transport=loopback;x_local={t_loop / t_local:.2f}",
        })
    finally:
        loop.shutdown()

    try:
        port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=120.0)
    except Exception as e:  # noqa: BLE001 - no-subprocess environments
        rows.append({
            "name": "fig7/FAILED", "s": -1.0,
            "derived": f"cluster spawn failed: {e}"[:200].replace(",", ";"),
        })
        return rows
    try:
        cprog = port.localities()[0].devices[0].create_program([_KERNEL], name="fig7-cluster").get()
        t_cluster = _time_launch(cprog, x, iters)
        rows.append({
            "name": f"fig7/cluster_launch_n{n}", "s": t_cluster,
            "derived": f"transport=cluster;x_local={t_cluster / t_local:.2f}",
        })
    finally:
        port.shutdown()
    return rows
