"""Fig. 7 (extension): remote-launch overhead — local vs loopback vs cluster.

The paper's §5 protocol (``benchmarks/common.timeit``: 11 iterations,
first discarded) applied to the same registered kernel launched three
ways:

* ``local``    — ``Program.run`` on this process's device (baseline),
* ``loopback`` — through a ``LoopbackParcelport`` locality: the full
  parcel path (encode, action dispatch, reply decode) without process
  hops — the codec + dispatch cost in isolation,
* ``cluster``  — through a ``LocalClusterParcelport`` worker process:
  adds the real IPC hop and cross-process scheduling.

Derived columns report the multiple over the local baseline, so the
transport tax is tracked per-PR in ``BENCH_remote.json`` alongside the
futurization (BENCH_overhead) and scaling (BENCH_multidevice) numbers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit

_KERNEL = "partition_map_ref"


def _time_launch(prog, x, iters: int) -> float:
    def launch():
        prog.run([x], _KERNEL).get()

    launch()  # warm-up: compile / create remote executables outside the clock
    return timeit(launch, iters=iters)


def _time_launch_inflight(prog, x, iters: int, depth: int) -> float:
    """Mean per-launch time with ``depth`` launches in flight at once —
    the pipelined channel ships later parcels while earlier ones are
    still executing remotely, so the wire hop amortizes away."""
    def burst():
        futs = [prog.run([x], _KERNEL) for _ in range(depth)]
        for f in futs:
            f.get()

    burst()
    return timeit(burst, iters=iters) / depth


def run(quick: bool = False):
    from repro.core import LocalClusterParcelport, LoopbackParcelport, Program, get_all_devices
    from repro.core.parcel import resolve_kernel

    iters = 4 if quick else 11
    n = 1 << (12 if quick else 14)
    x = np.random.default_rng(0).normal(size=(n,)).astype(np.float32)
    rows = []

    dev = get_all_devices().get()[0]
    prog = Program(dev, {_KERNEL: resolve_kernel(_KERNEL)}, "fig7")
    t_local = _time_launch(prog, x, iters)
    rows.append({"name": f"fig7/local_launch_n{n}", "s": t_local, "derived": "transport=local"})

    loop = LoopbackParcelport(n_localities=1)
    try:
        rprog = loop.localities()[0].devices[0].create_program([_KERNEL], name="fig7-loop").get()
        t_loop = _time_launch(rprog, x, iters)
        rows.append({
            "name": f"fig7/loopback_launch_n{n}", "s": t_loop,
            "derived": f"transport=loopback;x_local={t_loop / t_local:.2f}",
        })
    finally:
        loop.shutdown()

    try:
        port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=120.0)
    except Exception as e:  # noqa: BLE001 - no-subprocess environments
        rows.append({
            "name": "fig7/FAILED", "s": -1.0,
            "derived": f"cluster spawn failed: {e}"[:200].replace(",", ";"),
        })
        return rows
    try:
        cprog = port.localities()[0].devices[0].create_program([_KERNEL], name="fig7-cluster").get()
        t_cluster = _time_launch(cprog, x, iters)
        rows.append({
            "name": f"fig7/cluster_launch_n{n}", "s": t_cluster,
            "derived": f"transport=cluster;x_local={t_cluster / t_local:.2f}",
        })
        # Pipelined depth-8: per-launch time with 8 parcels in flight —
        # the channel stages+flushes without blocking on replies, so the
        # round trips overlap remote execution (serial launch = depth 1).
        t_pipe = _time_launch_inflight(cprog, x, iters, depth=8)
        rows.append({
            "name": f"fig7/cluster_pipelined8_n{n}", "s": t_pipe,
            "derived": f"transport=cluster;x_serial={t_pipe / t_cluster:.2f}",
        })
    finally:
        port.shutdown()

    # Shared-memory array lane at a size where it pays (1 MB payload:
    # the pipe's per-byte cost dominates its fixed cost) — the same
    # launch with the lane forced off isolates the transfer tax.
    n_big = 1 << 18
    big = np.random.default_rng(1).normal(size=(n_big,)).astype(np.float32)
    for label, shm in (("shm", True), ("inline", False)):
        try:
            sport = LocalClusterParcelport(n_workers=1, heartbeat_timeout=120.0, shm=shm)
        except Exception as e:  # noqa: BLE001 - no-subprocess environments
            rows.append({
                "name": "fig7/FAILED", "s": -1.0,
                "derived": f"cluster spawn failed: {e}"[:200].replace(",", ";"),
            })
            return rows
        try:
            sprog = sport.localities()[0].devices[0].create_program([_KERNEL], name=f"fig7-{label}").get()
            t = _time_launch(sprog, big, iters)
            rows.append({"name": f"fig7/cluster_{label}_launch_n{n_big}", "s": t,
                         "derived": f"transport=cluster+{label}"})
        finally:
            sport.shutdown()
    t_shm = next(r["s"] for r in rows if "cluster_shm_" in r["name"])
    t_inl = next(r["s"] for r in rows if "cluster_inline_" in r["name"])
    rows[-2]["derived"] += f";x_inline={t_shm / t_inl:.2f}"
    return rows
