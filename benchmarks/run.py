"""Benchmark driver — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows (paper §5 protocol: 11
iterations, first discarded, mean of the remaining 10).  The overhead
module's rows are additionally written to ``BENCH_overhead.json``, the
fig6 multi-device rows (incl. per-policy scheduler rows) to
``BENCH_multidevice.json``, the fig7 remote-transport rows (local vs
loopback vs cluster launch) to ``BENCH_remote.json``, the fig8
stream-overlap rows (1-stream serialized vs 2-stream double-buffered
pipeline) to ``BENCH_overlap.json``, the fig9 serving rows
(continuous batching vs per-request serial, 1 and 8 devices) to
``BENCH_serving.json``, and the fig10 elastic-training rows (tokens/s at
1→4 localities, with and without a mid-run worker kill) to
``BENCH_training.json`` so the native/futurized/graph gap, the
1→4-device scaling trajectory, the parcel-transport tax, the
transfer–compute overlap win, the batching throughput win and the
kill-and-recover training property are all tracked per-PR.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig3,...]
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import traceback

MODULES = [
    ("overhead", "benchmarks.overhead"),
    ("fig3", "benchmarks.fig3_stencil"),
    ("fig4", "benchmarks.fig4_partition"),
    ("fig5", "benchmarks.fig5_mandelbrot"),
    ("fig6", "benchmarks.fig6_multidevice"),
    ("fig7", "benchmarks.fig7_remote"),
    ("fig8", "benchmarks.fig8_overlap"),
    ("fig9", "benchmarks.fig9_serving"),
    ("fig10", "benchmarks.fig10_training"),
    ("roofline", "benchmarks.roofline_table"),
]


def _fig6_speedups(rows) -> None:
    """Append ``speedup_vs_dev1`` to every fig6 partition row's derived
    field (the scaling trajectory CI gates on), computed against the
    same-size dev1 row."""
    base = {}
    for r in rows:
        name = str(r.get("name", ""))
        if name.startswith("fig6/partition_n") and name.endswith("_dev1") and r["s"] > 0:
            base[name[: -len("_dev1")]] = r["s"]
    for r in rows:
        name = str(r.get("name", ""))
        stem, sep, _ = name.rpartition("_dev")
        if not (sep and name.startswith("fig6/partition_n")):
            continue
        b = base.get(stem)
        if b and r["s"] > 0:
            d = str(r.get("derived", ""))
            r["derived"] = f"{d};speedup_vs_dev1={b / r['s']:.2f}" if d else f"speedup_vs_dev1={b / r['s']:.2f}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes/iterations")
    ap.add_argument("--only", default="", help="comma-separated subset of module tags")
    args = ap.parse_args()
    only = {t for t in args.only.split(",") if t}

    print("name,us_per_call,derived")
    failed = 0
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(quick=args.quick)
            if tag == "fig6":
                _fig6_speedups(rows)
            # Subprocess-based modules report breakage as a */FAILED data
            # row; that must fail the driver (and CI), not pass silently.
            if any(str(r.get("name", "")).endswith("/FAILED") for r in rows):
                failed += 1
            for r in rows:
                derived = str(r.get("derived", "")).replace(",", ";")
                print(f"{r['name']},{r['s'] * 1e6:.1f},{derived}", flush=True)
            json_out = {
                "overhead": "BENCH_overhead.json",
                "fig6": "BENCH_multidevice.json",
                "fig7": "BENCH_remote.json",
                "fig8": "BENCH_overlap.json",
                "fig9": "BENCH_serving.json",
                "fig10": "BENCH_training.json",
            }.get(tag)
            if json_out:
                payload = {
                    "quick": args.quick,
                    "rows": [
                        {"name": r["name"], "us": r["s"] * 1e6, "derived": str(r.get("derived", ""))}
                        for r in rows
                    ],
                }
                with open(json_out, "w") as fh:
                    json.dump(payload, fh, indent=2)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{tag}/CRASHED,-1,{traceback.format_exc(limit=3).splitlines()[-1]}", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
