"""Roofline summary rows from the dry-run records (EXPERIMENTS.md source).

Not a timing benchmark: converts the per-cell dry-run JSON into the three
roofline terms (seconds at v5e peaks) so ``benchmarks.run`` emits the
whole table alongside the timed benchmarks.
"""
from __future__ import annotations

from repro.analysis.roofline import load_records, roofline_terms


def run(quick: bool = False):
    rows = []
    for rec in load_records(multi_pod=False):
        if "error" in rec:
            rows.append({"name": f"roofline/{rec['arch']}:{rec['shape']}", "s": -1.0, "derived": "ERROR"})
            continue
        t = roofline_terms(rec)
        rows.append(
            {
                "name": f"roofline/{rec['arch']}:{rec['shape']}",
                "s": t["step_seconds"],
                "derived": (
                    f"bound={t['bound']};compute={t['compute_s']:.2e};memory={t['memory_s']:.2e};"
                    f"collective={t['collective_s']:.2e};useful_flops_frac={t['model_flops_ratio']:.2f}"
                ),
            }
        )
    return rows
