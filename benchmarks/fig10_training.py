"""Fig. 10 (systems extension): elastic data-parallel training throughput,
1 -> 4 localities, with and without a mid-run worker kill.

Each locality is a ``LocalWorker`` pinned to one forced host device; the
shard step is the captured-graph replay from ``repro.training.elastic``.
As in fig6/fig8, the per-device clock is modeled: every worker *occupies
its device lane* for ``shard_tokens / OCC_TOKENS_PER_S`` (a GIL-releasing
sleep) before running the real shard math, because N forced host devices
share one set of cores and can never genuinely beat 1 on raw CPU compute.
Everything the elastic trainer is responsible for — sharding, dispatch,
parcel-format gradient replies, driver-side all-reduce, the jitted update
— runs for real; only the device clock is synthetic.

The ``train_kill_w4`` row arms the fault injector: one worker dies inside
its shard at a fixed step, the step re-executes resharded over the three
survivors, and the run completes.  ``recovery_identical=1`` in its derived
field asserts the post-kill loss curve is bit-identical to a clean
3-worker run seeded from the same state — the DESIGN.md §16 recovery
property, gated by CI alongside ``w4 >= 2x w1`` tokens/s.

jax fixes the device count at first init, so this benchmark re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count=4``
and parses the CSV it prints.
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
import time
import numpy as np
from repro.core import get_all_devices
from repro.training.elastic import ElasticTrainer

quick = bool(int(os.environ.get("BENCH_QUICK", "0")))
BATCH, SEQ = 8, 64
STEPS = 4 if quick else 8           # timed steps per row
WARM = 1 if quick else 2            # untimed: capture + compile + first replay
OCC_TOKENS_PER_S = 2000.0           # modeled device clock (module docstring)
TOTAL = WARM + STEPS                # one LR horizon for every row

devices = get_all_devices(1, 0).get()
assert len(devices) == 4, devices

def make(workers, **kw):
    return ElasticTrainer(
        "olmo-1b", use_smoke=True, batch=BATCH, seq=SEQ, seed=0,
        workers=workers, devices=devices[:workers],
        occupancy_tokens_per_s=OCC_TOKENS_PER_S, total_steps=TOTAL, **kw)

# --- scaling: tokens/s at 1, 2, 4 localities --------------------------------
for w in (1, 2, 4):
    t = make(w)
    try:
        t.run(WARM)
        t0 = time.perf_counter()
        t.run(STEPS)
        dt = time.perf_counter() - t0
    finally:
        t.close()
    tps = STEPS * BATCH * SEQ / dt
    print(f"CSVROW,fig10/train_w{w},{dt / STEPS * 1e6:.1f},workers={w};tokens_per_s={tps:.0f}")

# --- chaos row: mid-step kill at 4 localities, recovery gated ---------------
warm3 = make(3)  # pre-warm the survivor shard shapes: the kill row should
try:             # measure re-execution + resharding, not graph capture
    warm3.run(1)
finally:
    warm3.close()

t = make(4)
try:
    t.run(WARM)
    snap = t.snapshot()                      # state AT the kill step
    kill_step = t.cursor
    t.workers[1].kill_at_step(kill_step)     # dies inside its shard
    t0 = time.perf_counter()
    tail = t.run(STEPS)["losses"]
    dt = time.perf_counter() - t0
    deaths = [e for e in t.events if e[0] == "death"]
    assert len(deaths) == 1 and len(t.active_workers()) == 3, t.events
finally:
    t.close()

ref = ElasticTrainer(                        # clean 3-worker run, same state
    "olmo-1b", use_smoke=True, batch=BATCH, seq=SEQ, seed=0,
    workers=3, devices=devices[:3], occupancy_tokens_per_s=OCC_TOKENS_PER_S,
    total_steps=TOTAL, state=(snap["params"], snap["opt_state"]),
    start_step=snap["step"])
try:
    ref_tail = ref.run(STEPS)["losses"]
finally:
    ref.close()

identical = int(tail == ref_tail)
tps = STEPS * BATCH * SEQ / dt               # re-executed step counted once
print(f"CSVROW,fig10/train_kill_w4,{dt / STEPS * 1e6:.1f},"
      f"workers=4;kill_step={kill_step};deaths=1;tokens_per_s={tps:.0f};"
      f"recovery_identical={identical}")
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("CSVROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append({"name": name, "s": float(us) / 1e6, "derived": derived})
    if len(rows) < 4 or proc.returncode != 0:
        # Partial output (e.g. a crash in the chaos section) must fail the
        # driver — the recovery row is the one CI gates on.
        rows.append(
            {"name": "fig10/FAILED", "s": -1.0, "derived": proc.stderr.strip()[-200:].replace(",", ";")}
        )
    return rows
