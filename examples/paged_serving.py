"""Paged-KV serving demo (DESIGN.md §15).

A toy GQA language model is served by ``PagedServeEngine``: prompts of
assorted lengths are prefilled in token-budgeted groups, their KV lands
in fixed-size pages from a per-device ``PagePool``, and a continuous
decode lane steps every resident sequence over its page table through
the ``paged_attention`` reference kernel.  The same prompts then run
one-at-a-time for comparison, and the generated tokens are asserted
identical — paging and batching change the schedule, never the math.

    PYTHONPATH=src python examples/paged_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

PAGE = 8
MAXLEN = 64
VOCAB = 128
HEADS = 4
KV_HEADS = 2
HEAD_DIM = 16


def make_model():
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention.ref import paged_attention_ref

    Dm = HEADS * HEAD_DIM
    rng = np.random.default_rng(0)
    s = 1.0 / np.sqrt(Dm)
    emb = jnp.asarray(rng.normal(size=(VOCAB, Dm)).astype(np.float32) * s)
    wq = jnp.asarray(rng.normal(size=(Dm, Dm)).astype(np.float32) * s)
    wk = jnp.asarray(rng.normal(size=(Dm, KV_HEADS * HEAD_DIM)).astype(np.float32) * s)
    wv = jnp.asarray(rng.normal(size=(Dm, KV_HEADS * HEAD_DIM)).astype(np.float32) * s)
    wu = jnp.asarray(rng.normal(size=(Dm, VOCAB)).astype(np.float32) * s)

    @jax.jit
    def prefill_fn(tokens):
        x = emb[tokens]                                   # (B, T, Dm)
        B, T, _ = x.shape
        k = (x @ wk).reshape(B, T, KV_HEADS, HEAD_DIM)
        v = (x @ wv).reshape(B, T, KV_HEADS, HEAD_DIM)
        q = (x[:, -1] @ wq).reshape(B, KV_HEADS, HEADS // KV_HEADS, HEAD_DIM)
        sc = jnp.einsum("bkrd,btkd->bkrt", q, k) / np.sqrt(HEAD_DIM)
        o = jnp.einsum("bkrt,btkd->bkrd", jax.nn.softmax(sc, -1), v)
        nxt = jnp.argmax(o.reshape(B, Dm) @ wu, -1).astype(jnp.int32)
        return k[:, None], v[:, None], nxt                # KV gains a layer axis

    @jax.jit
    def decode_fn(kp, vp, tokens, positions, tables, lengths):
        x = emb[tokens]                                   # (b, Dm)
        b = tokens.shape[0]
        q = (x @ wq).reshape(b, HEADS, HEAD_DIM)
        k = (x @ wk).reshape(b, KV_HEADS, HEAD_DIM)
        v = (x @ wv).reshape(b, KV_HEADS, HEAD_DIM)
        page = tables[jnp.arange(b), positions // PAGE]
        kp = kp.at[0, page, positions % PAGE].set(k)      # scatter the new token
        vp = vp.at[0, page, positions % PAGE].set(v)
        o = paged_attention_ref(q, kp[0], vp[0], tables, lengths + 1)
        nxt = jnp.argmax(o.reshape(b, Dm) @ wu, -1).astype(jnp.int32)
        return kp, vp, nxt

    return prefill_fn, decode_fn


def main() -> None:
    from repro.serving import LanePolicy, PagedKVCache, PagedServeEngine, PageSpec

    prefill_fn, decode_fn = make_model()
    # prefill groups same-length prompts (no intra-group padding), so the
    # stream repeats a few lengths the way real traffic repeats templates
    prompts = [np.arange(n, dtype=np.int32) % VOCAB
               for n in (5, 5, 5, 12, 12, 12, 30, 30)]
    new = 8

    def serve(label, **policies):
        kv = PagedKVCache(PageSpec(1, PAGE, KV_HEADS, HEAD_DIM), pool_pages=64)
        with PagedServeEngine(kv, prefill_fn, decode_fn, max_seq_len=MAXLEN,
                              name=label, **policies) as eng:
            futs = [eng.submit(p, max_new_tokens=new) for p in prompts]
            outs = [np.asarray(f.get()) for f in futs]
            m = eng.metrics()
        print(f"{label:>10}: {m['prefill_batches']} prefill batches, "
              f"{m['decode_steps']} decode steps, "
              f"waste {m['padding_waste']:.2f}, "
              f"spilled {m['kv']['spilled_bytes']} B")
        return outs

    # one sequence at a time: every prompt pays its own prefill + decode
    serial = serve("serial", prefill=LanePolicy(max_batch=1, max_delay_s=0.0),
                   decode=LanePolicy(max_batch=1, max_delay_s=0.0))
    # disaggregated: grouped prefill, continuous batched decode
    paged = serve("paged",
                  prefill=LanePolicy(max_batch=8, max_delay_s=0.05,
                                     token_budget=128),
                  decode=LanePolicy(max_batch=8, max_delay_s=0.02),
                  decode_shapes=(1, 2, 4, 8))

    assert all(np.array_equal(a, b) for a, b in zip(serial, paged)), \
        "schedules diverged"
    print("tokens identical across schedules OK")


if __name__ == "__main__":
    main()
