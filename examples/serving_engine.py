"""Continuous-batching serving demo (DESIGN.md §12).

Many "clients" fire single-row requests at a ``RequestEngine``; the
engine assembles micro-batches under a latency deadline, pads them to
bucketed shapes, replays the captured step on an engine-owned stream,
and resolves each client's future with exactly its rows.  The same
stream is then replayed per-request (serial) for comparison — the
throughput gap is the reason the engine exists.

    PYTHONPATH=src python examples/serving_engine.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import Scheduler, get_all_devices, wait_all


def step(x):
    import jax
    from repro.kernels.partition_map.ref import partition_map_ref

    def body(i, v):
        return partition_map_ref(v) * 0.5 + v * 0.5

    return jax.lax.fori_loop(0, 4, body, x)


def main() -> None:
    from repro.serving import RequestEngine

    dev = get_all_devices().get()[0]
    rng = np.random.default_rng(0)
    payloads = [rng.normal(size=(1, 256)).astype(np.float32) for _ in range(48)]

    # -- per-request serial baseline ---------------------------------------
    prog = dev.create_program({"step": step}, "serve-demo").get()
    prog.run([payloads[0]], "step").get()  # warm the executable
    t0 = time.perf_counter()
    serial = [np.asarray(prog.run([p], "step").get()) for p in payloads]
    t_serial = time.perf_counter() - t0

    # -- continuous batching ------------------------------------------------
    engine = RequestEngine(
        step,
        max_batch=8,
        max_delay_s=0.002,
        scheduler=Scheduler([dev], policy="least_loaded"),
        name="demo",
    )
    wait_all([engine.submit(p) for p in payloads])  # warm the bucket routes
    t0 = time.perf_counter()
    futs = [engine.submit(p) for p in payloads]
    wait_all(futs)
    t_batched = time.perf_counter() - t0

    for want, f in zip(serial, futs):
        got = f.get()
        assert got.dtype == want.dtype and np.array_equal(got, want), "diverged"

    m = engine.metrics()
    n = len(payloads)
    print(f"{n} requests, step=(1,256) fori_loop x4")
    print(f"  serial : {t_serial * 1e3:7.1f} ms  ({n / t_serial:7.0f} req/s)")
    print(
        f"  engine : {t_batched * 1e3:7.1f} ms  ({n / t_batched:7.0f} req/s)  "
        f"[{m['batches']} micro-batches incl. warm-up, "
        f"mean {m['mean_batch_rows']:.1f} rows]"
    )
    print(f"  speedup: {t_serial / t_batched:.2f}x, results bit-equal")
    engine.close()


if __name__ == "__main__":
    main()
