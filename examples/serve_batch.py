"""Batched serving: prefill a batch of prompts, then greedy-decode.

Uses the same serve_step the decode_* dry-run cells lower, on a reduced
config, with the KV cache donated between steps.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-moe --tokens 16
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import get_model
from repro.serving.serve_step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke(get_config(args.arch))
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(0))
    max_seq = args.prompt_len + args.tokens + 1

    cache = m.init_cache(cfg, args.batch, max_seq, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len))

    # prefill via repeated decode steps (smoke-sized; production uses
    # make_prefill which the prefill_32k dry-run cells lower)
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    for p in range(args.prompt_len):
        nxt, _logits, cache = serve(params, cache, tok, jnp.int32(p))
        tok = (
            jnp.asarray(prompt[:, p + 1 : p + 2], jnp.int32)
            if p + 1 < args.prompt_len
            else nxt
        )

    generated = []
    t0 = time.perf_counter()
    for i in range(args.tokens):
        nxt, _logits, cache = serve(params, cache, tok, jnp.int32(args.prompt_len + i))
        generated.append(np.asarray(nxt)[:, 0])
        tok = nxt
    dt = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    for b in range(args.batch):
        print(f"  prompt {prompt[b].tolist()} -> {gen[b].tolist()}")
    print(
        f"{args.tokens} tokens x {args.batch} seqs in {dt:.2f}s "
        f"({args.tokens * args.batch / dt:.1f} tok/s on CPU)"
    )


if __name__ == "__main__":
    main()
