"""Two-stream double buffering, overlapped with host-side work.

The stream-engine demo (DESIGN.md §11): a chunked pipeline — H2D ->
kernel -> D2H -> host consume — alternates chunks between two streams
over two buffer slots, with completion events serializing the kernels
onto one "compute engine" (the CUDA copy-engine pattern).  While the
device crunches chunk ``i``, stream ``i+1`` stages and ships the next
chunk, and the MAIN thread keeps doing its own work the whole time —
the paper's claim that transfers, launches and host computation all
overlap, in one page of code.

    PYTHONPATH=src python examples/overlap_pipeline.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import get_all_devices


def kernel(x, grid=None, block=None):
    import jax.numpy as jnp

    for _ in range(2):
        x = jnp.sin(x) * 1.0001 + x * 0.5
    return x


def main():
    dev = get_all_devices().get()[0]
    prog = dev.create_program({"work": kernel}, "overlap").get()

    n, nchunks = 1 << 20, 8
    rng = np.random.default_rng(0)
    chunks = [rng.normal(size=(n,)).astype(np.float32) for _ in range(nchunks)]

    # Double buffering: two slots, reused alternately.  Same-stream FIFO
    # guarantees slot i%2's previous read finished before it is rewritten.
    inb = [dev.create_buffer(n, np.float32).get() for _ in range(2)]
    outb = [dev.create_buffer(n, np.float32).get() for _ in range(2)]
    streams = [dev.create_stream("pipe-a"), dev.create_stream("pipe-b")]

    t0 = time.perf_counter()
    checksums, prev_kernel = [], None
    for i, chunk in enumerate(chunks):
        s = streams[i % 2]
        s.enqueue_write(inb[i % 2], 0, chunk)              # H2D on this stream
        if prev_kernel is not None:
            s.wait_event(prev_kernel)                      # one compute engine
        s.launch(prog, [inb[i % 2]], "work", out=[outb[i % 2]])
        prev_kernel = s.record()                           # fires at kernel COMPLETION
        r = s.enqueue_read(outb[i % 2])                    # D2H on this stream
        # Host-side consume, stream-ordered (cudaLaunchHostFunc analogue).
        checksums.append(s.submit(lambda f=r: float(np.abs(f.get()).sum())))

    # The pipeline is in flight — the main core is free.  Overlap it with
    # genuine host work (the paper's "work on the main cores").
    host_acc, host_rounds = 0.0, 0
    while not all(f.done() for f in checksums):
        host_acc += float(np.sin(np.arange(1 << 14)).sum())
        host_rounds += 1
    wall = time.perf_counter() - t0

    total = sum(f.get() for f in checksums)
    hwm = dev._dispatcher.high_water()
    print(f"pipelined {nchunks} chunks x {n * 4 / 1e6:.1f} MB in {wall * 1e3:.0f} ms")
    print(f"checksum {total:.1f}; host did {host_rounds} rounds of its own work meanwhile")
    print(f"peak concurrent lanes on {dev.key}: {hwm} (>1 == overlap really happened)")
    assert hwm > 1, "expected at least two lanes running concurrently"

    dev.synchronize()  # drains ALL streams (§11 fix), not just the default


if __name__ == "__main__":
    main()
