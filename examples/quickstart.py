"""Quickstart: the paper's Listing 1 + Listing 2 workflow, on JAX.

Discovers devices, allocates buffers, runtime-compiles a kernel from a
source file, overlaps data transfer with compilation via futures, runs
the kernel, reads the result back.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import Dim3, get_all_devices, wait_all


def main():
    # Listing 1: gather all (local and remote) devices with capability >= 1.0
    devices = get_all_devices(1, 0).get()
    print(f"devices: {devices}")
    dev = devices[0]

    # host data (Listing 2 lines 4-12)
    n = 1000
    input_data = np.ones(n, dtype=np.uint32)
    result = np.zeros(1, dtype=np.uint32)

    futures = []

    # buffers + async writes (lines 16-22): cudaMalloc + cudaMemcpyAsync
    inbuf = dev.create_buffer(n, np.uint32).get()
    futures.append(inbuf.enqueue_write(0, input_data))
    resbuf = dev.create_buffer(1, np.uint32).get()
    futures.append(resbuf.enqueue_write(0, result))

    # runtime kernel compilation from source (lines 24-25): NVRTC -> jax.jit
    kernel_src = textwrap.dedent(
        """
        import jax.numpy as jnp

        def sum_kernel(x, acc, grid=None, block=None):
            return acc + jnp.sum(x, dtype=acc.dtype)

        KERNELS = {"sum": sum_kernel}
        """
    )
    path = "/tmp/quickstart_kernel.py"
    with open(path, "w") as f:
        f.write(kernel_src)
    prog = dev.create_program_with_file(path).get()
    futures.append(prog.build("sum"))

    # barrier: copies + compilation must finish (line 38)
    wait_all(futures)

    # launch with explicit geometry (lines 27-40)
    prog.run([inbuf, resbuf], "sum", grid=Dim3(1), block=Dim3(32), out=[resbuf]).get()

    # synchronous read-back (line 42)
    res = resbuf.enqueue_read_sync(0, 1)
    print(f"sum of {n} ones = {int(res[0])}")
    assert int(res[0]) == n


if __name__ == "__main__":
    main()
