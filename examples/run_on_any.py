"""Scheduler-routed launches: "any kernel on any device" (DESIGN.md §9).

Forces 4 host devices, then drives the fig6 partition kernel through
``Program.run_on_any`` under each placement policy and captures a
multi-device graph that replays through one future.

    PYTHONPATH=src python examples/run_on_any.py
"""
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import Scheduler, capture, get_all_devices, get_all_localities, wait_all
from repro.kernels.partition_map.ref import partition_map_ref


def main():
    devices = get_all_devices(1, 0).get()
    print(f"fleet: {devices}")
    print(f"localities: {get_all_localities().get()}")

    def k(x):
        def body(i, v):
            return partition_map_ref(v) * 0.5 + v * 0.5

        return jax.lax.fori_loop(0, 32, body, x)

    prog = devices[0].create_program({"k": k}, "partition").get()
    # device-resident chunks, spread round-robin: affinity follows the AGAS
    # placement records (zero percolation); other policies pay the copies
    bufs = [
        devices[i % len(devices)]
        .create_buffer_from(np.random.default_rng(i).normal(size=(1 << 16,)).astype(np.float32))
        .get()
        for i in range(8)
    ]

    # one run_on_any pipeline per policy over the same partition workload
    for policy in ("static", "round_robin", "least_loaded", "affinity"):
        sched = Scheduler(devices, policy=policy)

        def pipeline():
            futs = [prog.run_on_any([b], "k", scheduler=sched) for b in bufs]
            wait_all(futs)
            return [f.get() for f in futs]

        pipeline()  # warm-up (compiles the per-device siblings)
        t0 = time.perf_counter()
        pipeline()
        dt = time.perf_counter() - t0
        print(f"{policy:>13}: {dt * 1e3:7.1f} ms  placements={sched.stats()}")

    # capture a multi-device graph through run_on_any, replay = ONE future
    d0, d1 = devices[0], devices[1]
    prog2 = d0.create_program({"inc": lambda x: x + 1.0, "scale": lambda x: x * 3.0}, "g").get()
    b_in = d0.create_buffer(16, np.float32).get()
    t_mid = d0.create_buffer(16, np.float32).get()
    t_out = d1.create_buffer(16, np.float32).get()
    rr = Scheduler([d0, d1], policy="round_robin")
    with capture("xdev") as g:
        b_in.enqueue_write(0, np.ones(16, np.float32))
        prog2.run_on_any([b_in], "inc", out=[t_mid], scheduler=rr)
        prog2.run_on_any([t_mid], "scale", out=[t_out], scheduler=rr)
        r = t_out.enqueue_read()
    exe = g.instantiate()
    print(exe)  # 2 fused segments, 1 transfer, fan-out
    res = exe.replay().get()
    print(f"graph result: {res[r][:4]} ... (expect 6.0 = (1+1)*3)")


if __name__ == "__main__":
    main()
