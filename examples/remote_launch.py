"""Remote launches end-to-end: "any kernel on any (local or remote) device".

Spawns a 2-worker ``LocalClusterParcelport`` (each worker is a separate
process — a real remote locality with its own JAX runtime and AGAS
registry), discovers the cluster-wide localities, places a kernel on a
remote one, overlaps the remote launch with local CPU work, and joins
everything with one ``wait_all`` — the paper's Listing 2 pattern stretched
across processes.

    PYTHONPATH=src python examples/remote_launch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    from repro.core import (
        LocalClusterParcelport,
        Program,
        async_,
        get_all_devices,
        get_all_localities,
        wait_all,
    )
    from repro.kernels.mandelbrot.ref import mandelbrot_ref
    from repro.kernels.partition_map.ref import partition_map_ref

    t0 = time.perf_counter()
    port = LocalClusterParcelport(n_workers=2, heartbeat_timeout=60.0)
    print(f"cluster up in {time.perf_counter() - t0:.1f}s")

    # 1. discover: local locality + every remote one the port reaches
    locs = get_all_localities(cluster=port).get()
    for loc in locs:
        print(f"  {loc}: {[d.key for d in loc]}")
    remote = next(l for l in locs if not l.is_local)
    rdev = remote.devices[0]

    # 2. place a kernel on the remote locality (percolates BY NAME — the
    #    worker resolves and runtime-compiles it there, NVRTC-style)
    prog = rdev.create_program(["mandelbrot"], name="mandel").get()
    t1 = time.perf_counter()
    remote_fut = prog.run([np.array([256, 256], np.int32)], "mandelbrot")

    # 3. overlap with local CPU work while the remote locality computes
    local_fut = async_(lambda: float(np.sum(np.asarray(partition_map_ref(
        np.random.default_rng(0).normal(size=(1 << 16,)).astype(np.float32))))))

    # 4. one barrier for both worlds (hpx::wait_all, Listing 2 l.38)
    wait_all([remote_fut, local_fut])
    dt = time.perf_counter() - t1
    img = np.asarray(remote_fut.get()[0])
    print(f"remote mandelbrot {img.shape} ({img.dtype}) + local reduce "
          f"{local_fut.get():.1f} overlapped in {dt * 1e3:.1f} ms")

    # 5. scheduler-routed: run_on_any(cluster=...) lets the percolation
    #    policy pick the locality (hpx::async(locality, action) by policy)
    dev = get_all_devices().get()[0]
    pm = Program(dev, {"partition_map_ref": partition_map_ref}, "pm")
    sched = port.scheduler()  # percolation policy over local + remote devices
    futs = [pm.run_on_any([np.full(4096, i, np.float32)], "partition_map_ref",
                          scheduler=sched) for i in range(8)]
    wait_all(futs)
    print(f"run_on_any placements: {sched.stats()}")

    port.shutdown()
    print("done")


if __name__ == "__main__":
    main()
