"""Partition pipeline (paper Fig. 4) as a reusable pattern.

Slices a vector into partitions and streams each through
H2D-copy -> kernel -> D2H-copy, with all three stages of different
partitions overlapping through the future graph. Prints sync vs
futurized timings.

    PYTHONPATH=src python examples/async_pipeline.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import get_all_devices, wait_all
from repro.kernels.partition_map.ops import partition_map


def main(n: int = 1 << 23, parts: int = 4):
    # n defaults large: per-partition work must dwarf the ~0.3 ms/hop host
    # thread cost on this 1-core container (paper: "negligible ... for
    # large enough vector sizes")
    dev = get_all_devices(1, 0).get()[0]
    prog = dev.create_program({"k": lambda x: partition_map(x, impl="ref")}, "pipeline").get()
    hosts = np.array_split(
        np.random.default_rng(0).normal(size=(n,)).astype(np.float32), parts
    )
    hosts = [np.ascontiguousarray(h) for h in hosts]
    jitted = jax.jit(lambda x: partition_map(x, impl="ref"))

    # warm-up both paths (runtime compilation happens here, asynchronously)
    futs = [dev.create_buffer_from(h) for h in hosts]
    wait_all([f.then(lambda b: prog.run([b], "k", out=[b]).get()) for f in futs])
    jitted(jax.numpy.asarray(hosts[0])).block_until_ready()

    # --- fully synchronous reference
    t0 = time.perf_counter()
    for h in hosts:
        x = jax.device_put(h)
        x.block_until_ready()
        y = jitted(x)
        y.block_until_ready()
        np.asarray(y)
    t_sync = time.perf_counter() - t0

    # --- futurized pipeline: stages overlap across partitions
    t0 = time.perf_counter()
    reads = []
    for h in hosts:
        buf = dev.create_buffer_from(h)  # async H2D
        ran = buf.then(lambda b: prog.run([b], "k", out=[b]).get())  # async launch
        reads.append(ran.then(lambda bl: bl[0].enqueue_read().get()))  # async D2H
    wait_all(reads)
    t_async = time.perf_counter() - t0

    print(f"partitions={parts} n={n}")
    print(f"synchronous: {t_sync * 1e3:8.2f} ms")
    print(f"futurized:   {t_async * 1e3:8.2f} ms   ({(t_sync - t_async) / t_sync:+.1%})")


if __name__ == "__main__":
    main()
