"""Task-graph capture & fused replay (CUDA Graphs analogue, DESIGN.md §8).

Drives the same three-kernel chain two ways:
  eager   — every launch pays a future + queue hop (Listing-2 style),
  graph   — the chain is captured once, fused into one jitted executable,
            and replayed with a single queue hop and a single future.

    PYTHONPATH=src python examples/graph_replay.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import get_all_devices
from repro.kernels.partition_map.ops import partition_map


def main(n: int = 1 << 18, steps: int = 50):
    dev = get_all_devices(1, 0).get()[0]
    prog = dev.create_program(
        {
            "scale": lambda x: x * 0.5,
            "map": lambda x: partition_map(x, impl="ref"),
            "shift": lambda x: x + 1.0,
        },
        "graph-demo",
    ).get()

    host = np.random.default_rng(0).normal(size=(n,)).astype(np.float32)
    src = dev.create_buffer_from(host).get()
    a = dev.create_buffer(n, np.float32).get()
    b = dev.create_buffer(n, np.float32).get()
    c = dev.create_buffer(n, np.float32).get()

    # --- eager chain (warm the executable cache first)
    def eager_step():
        prog.run([src], "scale", out=[a]).get()
        prog.run([a], "map", out=[b]).get()
        prog.run([b], "shift", out=[c]).get()

    eager_step()
    t0 = time.perf_counter()
    for _ in range(steps):
        eager_step()
    t_eager = (time.perf_counter() - t0) / steps

    # --- captured once, replayed fused (a and b become graph-internal:
    #     elided/donated inside the single fused executable)
    with dev.capture("chain") as g:
        prog.run([src], "scale", out=[a])
        prog.run([a], "map", out=[b])
        prog.run([b], "shift", out=[c])
        r = c.enqueue_read()
    exe = g.instantiate()
    print(exe)

    result = exe.replay().get()  # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        result = exe.replay().get()
    t_graph = (time.perf_counter() - t0) / steps

    final = result[r]
    print(f"n={n} steps={steps}  checksum={final.sum():.4f}")
    print(f"eager futurized: {t_eager * 1e6:9.1f} us/step  (3 hops, 3+ futures)")
    print(f"graph replay:    {t_graph * 1e6:9.1f} us/step  (1 hop, 1 future)  "
          f"[{(t_eager - t_graph) / t_eager:+.1%}]")


if __name__ == "__main__":
    main()
