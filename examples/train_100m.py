"""End-to-end driver: train a ~100M-parameter dense LM.

Full production path on one host: futurized data pipeline, microbatched
AdamW train step, async checkpointing, straggler monitor, resume.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 5 --tiny   # CI-sized

On CPU a full step of the 100M config takes O(10s); --tiny drops to a
~10M config for quick verification. Loss decreasing over the run is
asserted at exit.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from dataclasses import replace

from repro.configs import get_config
from repro.launch.train import train

# ~100M params: 12L x d640 x ff2560 + 32k vocab
ARCH_100M = dict(
    num_layers=12, d_model=640, num_heads=10, num_kv_heads=10,
    d_ff=2560, vocab_size=32000, head_dim=64, max_seq=1024,
)
ARCH_TINY = dict(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=1024, vocab_size=8192, head_dim=64, max_seq=512,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    base = get_config("olmo-1b")  # family template (dense, swiglu, rope)
    cfg = replace(base, name="dense-100m", **(ARCH_TINY if args.tiny else ARCH_100M))
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    # register the custom config so train() can fetch it
    import repro.configs as C

    C._MODULES = dict(C._MODULES)
    import types

    mod = types.ModuleType("repro.configs._custom100m")
    mod.CONFIG = cfg
    sys.modules["repro.configs._custom100m"] = mod
    C._MODULES[cfg.name] = "repro.configs._custom100m"

    out = train(
        cfg.name,
        use_smoke=False,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        resume=args.resume,
        log_every=max(1, args.steps // 50),
    )
    first = sum(out["losses"][:3]) / max(len(out["losses"][:3]), 1)
    last = sum(out["losses"][-3:]) / max(len(out["losses"][-3:]), 1)
    print(f"loss {first:.4f} -> {last:.4f}")
    if args.steps >= 20:  # too few steps are still inside LR warm-up
        assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
