"""Execute every fenced ``python`` block in the given docs, so the
tutorial can never rot.

    PYTHONPATH=src python tools/run_doc_snippets.py docs README.md

Each ` ```python ` block runs in its own subprocess under the caller's
``PYTHONPATH`` (tier-1 environment) with a hard timeout; a block is
skipped only when tagged ` ```python no-run ` (reserved for fragments
that are deliberately incomplete — currently none).  Blocks run in file
order, every file independent, and the first failure names the file,
block number and starting line, then dumps the block and its stderr.
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

_FENCE = re.compile(r"^```python[ \t]*(?P<tag>no-run)?[ \t]*$")
_TIMEOUT_S = 600


def extract_blocks(path: "pathlib.Path") -> "list[tuple[int, str]]":
    """(starting line, source) of every runnable python block in ``path``."""
    blocks = []
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m is None:
            i += 1
            continue
        start = i + 2  # 1-based line of the block's first source line
        body = []
        i += 1
        while i < len(lines) and lines[i].rstrip() != "```":
            body.append(lines[i])
            i += 1
        i += 1
        if m.group("tag") != "no-run":
            blocks.append((start, "\n".join(body) + "\n"))
    return blocks


def run_block(path: "pathlib.Path", line: int, src: str, index: int) -> bool:
    with tempfile.NamedTemporaryFile("w", suffix=f"_snippet{index}.py", delete=False) as f:
        f.write(src)
        tmp = f.name
    try:
        proc = subprocess.run(
            [sys.executable, tmp],
            capture_output=True,
            text=True,
            timeout=_TIMEOUT_S,
            env=dict(os.environ),
        )
    finally:
        os.unlink(tmp)
    if proc.returncode != 0:
        print(f"FAIL {path}:{line} (block {index})", file=sys.stderr)
        print("----- block -----", file=sys.stderr)
        print(src, file=sys.stderr)
        print("----- stderr -----", file=sys.stderr)
        print(proc.stderr[-4000:], file=sys.stderr)
        return False
    return True


def main(argv: "list[str]") -> int:
    targets: "list[pathlib.Path]" = []
    for arg in argv or ["docs"]:
        p = pathlib.Path(arg)
        if p.is_dir():
            targets.extend(sorted(p.glob("**/*.md")))
        elif p.exists():
            targets.append(p)
        else:
            print(f"no such file or directory: {arg}", file=sys.stderr)
            return 2

    total = 0
    for path in targets:
        blocks = extract_blocks(path)
        for i, (line, src) in enumerate(blocks, 1):
            print(f"RUN  {path}:{line} (block {i}/{len(blocks)})", flush=True)
            if not run_block(path, line, src, i):
                return 1
            total += 1
    print(f"OK   {total} snippet(s) across {len(targets)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
